"""jit'd wrappers: FastGRNN params pytree -> padded kernel layout -> run.

Two entry points live here:

  * ``fastgrnn_window_kernel`` — the fused full-window scan (training/eval
    batch path, one kernel launch per 128-sample window);
  * ``Q15StreamStep`` — the batched *single-step* path for multi-stream
    streaming inference (serve/streaming.py), stepping thousands of
    independent hidden states at once from Q15 weights.

Padding to hardware-aligned tiles: H=16, d=3 pads to Hp=Dp=128 lanes; the
zero lanes are inert (zero weights, zero state).  Low-rank factors are
pre-multiplied into effective W^T/U^T once per deployment (the MCU code
does the same factor-order trick at runtime; on TPU the 128x128 effective
matmul is a single MXU op, so pre-multiplying is strictly better)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastgrnn as fg
from repro.core.lut import make_lut
from . import qstep
from .kernel import fastgrnn_window, B_TILE

HP = 128


def _pad2(a, r, c):
    return jnp.pad(jnp.asarray(a, jnp.float32),
                   ((0, r - a.shape[0]), (0, c - a.shape[1])))


def _pad1(a, n):
    return jnp.pad(jnp.asarray(a, jnp.float32), (0, n - a.shape[0]))


def fastgrnn_window_kernel(params, xs, *, interpret: bool = True):
    """xs: (T, B, d) -> (h_final (B, H), traj (T, B, H)) via the Pallas
    kernel, LUT-activated (nearest mode, matching the deployed C engine)."""
    T, B, d = xs.shape
    H = params["b_z"].shape[0]
    W = fg.effective_W(params)      # (H, d)
    U = fg.effective_U(params)      # (H, H)
    zeta = 1.0 / (1.0 + np.exp(-float(params["zeta"])))
    nu = 1.0 / (1.0 + np.exp(-float(params["nu"])))

    bpad = -B % B_TILE
    xs_p = jnp.pad(jnp.asarray(xs, jnp.float32),
                   ((0, 0), (0, bpad), (0, HP - d)))
    h, traj = fastgrnn_window(
        jnp.asarray(make_lut("sigmoid")), jnp.asarray(make_lut("tanh")),
        xs_p,
        _pad2(W.T, HP, HP), _pad2(U.T, HP, HP),
        _pad1(params["b_z"], HP), _pad1(params["b_h"], HP),
        jnp.asarray([zeta, nu], jnp.float32),
        T=T, interpret=interpret)
    return h[:B, :H], traj[:, :B, :H]


# ---------------------------------------------------------------------------
# Batched single-step entry point (streaming)
# ---------------------------------------------------------------------------

class Q15StreamStep:
    """Batched single-step FastGRNN over Q15 weights: the hot path of the
    multi-stream streaming engine.  ``step(h, x, active)`` advances every
    slot whose ``active`` flag is set by one sample; ``head_logits`` maps
    any subset of slot states to classifier logits (emission time only).

    Backends (selected at construction):

      * ``"exact"``  — vectorized NumPy.  Guaranteed bit-identical per
        stream to the scalar ``core/qruntime.QRuntime`` reference: the
        batched ops are the same scalar IEEE-754 f32 ops per row, and NumPy
        never contracts mul+add into an FMA.  This is the agreement-contract
        backend (paper contribution (i) at batch scale) and the CPU default.
      * ``"jit"``    — the same qstep math jit-compiled with XLA.  Faster
        per tick on accelerators, but XLA's CPU emitter contracts
        ``a*b + c`` into FMAs (even through ``lax.optimization_barrier``),
        so hidden states drift ~1e-9/step from the reference; argmax
        predictions still agree in practice.
      * ``"pallas"`` — the ``kernel.fastgrnn_step`` Pallas kernel
        (interpret mode on CPU, compiled on TPU), dequantizing the int16
        weights on use inside the kernel.

    All backends share the single generic op sequence in ``qstep.py``.
    """

    BACKENDS = ("exact", "jit", "pallas")

    def __init__(self, qp_or_sw, *, act_scales=None, naive_acts=False,
                 backend: str = "exact", interpret: bool = True,
                 device=None):
        if backend not in self.BACKENDS:
            raise ValueError(f"backend must be one of {self.BACKENDS}")
        if isinstance(qp_or_sw, qstep.StepWeights):
            self.sw = qp_or_sw
        else:
            self.sw = qstep.StepWeights.from_quantized(
                qp_or_sw, act_scales=act_scales, naive_acts=naive_acts)
        self.backend = backend
        self.interpret = interpret
        # ``device``: pin the jit/pallas dispatch (weight constants AND the
        # per-tick inputs) to one jax device — the fleet's per-shard
        # placement hook.  None = default device; the exact backend is
        # process-local NumPy and ignores it.
        self.device = device if backend != "exact" else None
        self._np_arrs = self.sw.arrays(np)
        if backend == "exact":
            self._step = self._step_exact
        elif backend == "jit":
            self._jnp_arrs = self.sw.arrays(jnp)
            if self.device is not None:
                self._jnp_arrs = {k: jax.device_put(v, self.device)
                                  for k, v in self._jnp_arrs.items()}
            self._step = self._build_jit()
        else:
            from .kernel import make_fastgrnn_step
            self._pallas_step = make_fastgrnn_step(
                self.sw, hp=HP, interpret=interpret)
            self._step = self._step_pallas

    # -- state management ---------------------------------------------------
    @property
    def hidden_dim(self) -> int:
        return self.sw.hidden_dim

    @property
    def input_dim(self) -> int:
        return self.sw.input_dim

    def init_state(self, n_slots: int) -> np.ndarray:
        return np.zeros((n_slots, self.sw.hidden_dim), np.float32)

    def reset(self, h: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Zero the hidden state of every slot whose mask bit is set."""
        return np.where(np.asarray(mask)[:, None], np.float32(0.0),
                        np.asarray(h)).astype(np.float32)

    def head_logits(self, h: np.ndarray) -> np.ndarray:
        """Classifier logits for every slot state, (S, H) -> (S, C), via the
        fixed-order f32 head matvec (bit-identical to qruntime.run_window)."""
        return qstep.logits_batched(np, self._np_arrs, self.sw,
                                    np.asarray(h, np.float32))

    # -- one tick -----------------------------------------------------------
    def step(self, h, x, active):
        """h: (S, H) f32, x: (S, d) f32, active: (S,) bool -> h_new (S, H)
        as a NumPy array.  Slots with ``active=False`` keep their hidden
        state bit-for-bit.  Logits are NOT computed here — the engine only
        needs them at emission time; call :meth:`head_logits` on the
        emitting rows."""
        return self._step(np.asarray(h, np.float32),
                          np.asarray(x, np.float32),
                          np.asarray(active, bool))

    def _step_exact(self, h, x, active):
        h_new = qstep.step_batched(np, self._np_arrs, self.sw, h, x)
        return np.where(active[:, None], h_new, h).astype(np.float32)

    # -- scheduler/program adapter ------------------------------------------
    def step_rows(self, h, x, active, rows=None):
        """Slot-program adapter for ``serve/scheduler.SlotScheduler``
        consumers: advance exactly the slots listed in ``rows`` (the
        precomputed ``np.nonzero(active)[0]``; derived here if omitted).

        The exact backend computes *only* those rows — ``step_batched`` is
        row-independent (one fixed-order f32 matvec chain per row), so the
        gathered computation is bit-identical to the masked full-batch step
        while skipping idle slots entirely (partial-occupancy ticks no
        longer pay for the whole slot table).  The jit/pallas backends keep
        the fixed-shape masked step: a varying row count would retrace /
        repad every tick, costing more than the skipped rows save."""
        if self.backend != "exact":
            # the masked full-batch step never needs the row list — skip
            # the nonzero scan entirely (it is measurable at 100k+ slots)
            return self._step(np.asarray(h, np.float32),
                              np.asarray(x, np.float32),
                              np.asarray(active, bool))
        if rows is None:
            rows = np.nonzero(active)[0]
        if rows.size == 0:
            return np.asarray(h, np.float32)
        h = np.asarray(h, np.float32).copy()
        h[rows] = qstep.step_batched(np, self._np_arrs, self.sw,
                                     h[rows], np.asarray(x, np.float32)[rows])
        return h

    def _build_jit(self):
        arrs, sw, dev = self._jnp_arrs, self.sw, self.device

        @jax.jit
        def f(h, x, active):
            h_new = qstep.step_batched(jnp, arrs, sw, h, x)
            return jnp.where(active[:, None], h_new, h)

        if dev is None:
            return lambda h, x, active: np.asarray(f(h, x, active))
        # committed inputs steer the compiled computation onto the shard's
        # device (the closure constants above are already resident there)
        return lambda h, x, active: np.asarray(
            f(jax.device_put(h, dev), jax.device_put(x, dev),
              jax.device_put(active, dev)))

    def _step_pallas(self, h, x, active):
        S, H = h.shape
        sp = -S % B_TILE
        h_p = np.zeros((S + sp, HP), np.float32)
        h_p[:S, :H] = h
        x_p = np.zeros((S + sp, HP), np.float32)
        x_p[:S, :x.shape[1]] = x
        m_p = np.zeros((S + sp,), np.int32)
        m_p[:S] = active
        if self.device is not None:
            args = (jax.device_put(x_p, self.device),
                    jax.device_put(h_p, self.device),
                    jax.device_put(m_p, self.device))
        else:
            args = (jnp.asarray(x_p), jnp.asarray(h_p), jnp.asarray(m_p))
        h_new = self._pallas_step(*args)
        return np.asarray(h_new)[:S, :H]
