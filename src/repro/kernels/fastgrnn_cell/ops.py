"""jit'd wrappers: FastGRNN params pytree -> padded kernel layout -> run.

Two entry points live here:

  * ``fastgrnn_window_kernel`` — the fused full-window scan (training/eval
    batch path, one kernel launch per 128-sample window);
  * ``Q15StreamStep`` — the batched *single-step* path for multi-stream
    streaming inference (serve/streaming.py), stepping thousands of
    independent hidden states at once from Q15 weights.

Padding to hardware-aligned tiles: H=16, d=3 pads to Hp=Dp=128 lanes; the
zero lanes are inert (zero weights, zero state).  Low-rank factors are
pre-multiplied into effective W^T/U^T once per deployment (the MCU code
does the same factor-order trick at runtime; on TPU the 128x128 effective
matmul is a single MXU op, so pre-multiplying is strictly better)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastgrnn as fg
from repro.core.lut import make_lut
from repro.obs.transfers import TransferLedger
from . import qstep
from .kernel import fastgrnn_window, B_TILE

HP = 128


def _pad2(a, r, c):
    return jnp.pad(jnp.asarray(a, jnp.float32),
                   ((0, r - a.shape[0]), (0, c - a.shape[1])))


def _pad1(a, n):
    return jnp.pad(jnp.asarray(a, jnp.float32), (0, n - a.shape[0]))


def fastgrnn_window_kernel(params, xs, *, interpret: bool = True):
    """xs: (T, B, d) -> (h_final (B, H), traj (T, B, H)) via the Pallas
    kernel, LUT-activated (nearest mode, matching the deployed C engine)."""
    T, B, d = xs.shape
    H = params["b_z"].shape[0]
    W = fg.effective_W(params)      # (H, d)
    U = fg.effective_U(params)      # (H, H)
    zeta = 1.0 / (1.0 + np.exp(-float(params["zeta"])))
    nu = 1.0 / (1.0 + np.exp(-float(params["nu"])))

    bpad = -B % B_TILE
    xs_p = jnp.pad(jnp.asarray(xs, jnp.float32),
                   ((0, 0), (0, bpad), (0, HP - d)))
    h, traj = fastgrnn_window(
        jnp.asarray(make_lut("sigmoid")), jnp.asarray(make_lut("tanh")),
        xs_p,
        _pad2(W.T, HP, HP), _pad2(U.T, HP, HP),
        _pad1(params["b_z"], HP), _pad1(params["b_h"], HP),
        jnp.asarray([zeta, nu], jnp.float32),
        T=T, interpret=interpret)
    return h[:B, :H], traj[:, :B, :H]


# ---------------------------------------------------------------------------
# Batched single-step entry point (streaming)
# ---------------------------------------------------------------------------

class Q15StreamStep:
    """Batched single-step FastGRNN over Q15 weights: the hot path of the
    multi-stream streaming engine.  ``step(h, x, active)`` advances every
    slot whose ``active`` flag is set by one sample; ``head_logits`` maps
    any subset of slot states to classifier logits (emission time only).

    Backends (selected at construction):

      * ``"exact"``  — vectorized NumPy.  Guaranteed bit-identical per
        stream to the scalar ``core/qruntime.QRuntime`` reference: the
        batched ops are the same scalar IEEE-754 f32 ops per row, and NumPy
        never contracts mul+add into an FMA.  This is the agreement-contract
        backend (paper contribution (i) at batch scale) and the CPU default.
      * ``"jit"``    — the same qstep math jit-compiled with XLA.  Faster
        per tick on accelerators, but XLA's CPU emitter contracts
        ``a*b + c`` into FMAs (even through ``lax.optimization_barrier``),
        so hidden states drift ~1e-9/step from the reference; argmax
        predictions still agree in practice.
      * ``"pallas"`` — the ``kernel.fastgrnn_step`` Pallas kernel
        (interpret mode on CPU, compiled on TPU), dequantizing the int16
        weights on use inside the kernel.

    All backends share the single generic op sequence in ``qstep.py``.
    """

    BACKENDS = ("exact", "jit", "pallas")

    def __init__(self, qp_or_sw, *, act_scales=None, naive_acts=False,
                 backend: str = "exact", interpret: bool = True,
                 device=None, mxu: bool = False):
        if backend not in self.BACKENDS:
            raise ValueError(f"backend must be one of {self.BACKENDS}")
        if isinstance(qp_or_sw, qstep.StepWeights):
            self.sw = qp_or_sw
        else:
            self.sw = qstep.StepWeights.from_quantized(
                qp_or_sw, act_scales=act_scales, naive_acts=naive_acts)
        self.backend = backend
        self.interpret = interpret
        if mxu and backend != "pallas":
            raise ValueError("mxu=True requires the pallas backend (the "
                             "128-lane MXU layout is a Pallas lowering)")
        self.mxu = bool(mxu)
        # host<->device byte accounting (always on — plain int adds); the
        # fleet/engine stats() surface this and the zero-copy regression
        # test reads it (see repro.obs.transfers)
        self.transfers = TransferLedger()
        # ``device``: pin the jit/pallas dispatch (weight constants AND the
        # per-tick inputs) to one jax device — the fleet's per-shard
        # placement hook.  None = default device; the exact backend is
        # process-local NumPy and ignores it.
        self.device = device if backend != "exact" else None
        self._np_arrs = self.sw.arrays(np)
        # Numeric-health seam (repro.obs.numerics): when an engine sets
        # this to a mutable dict, the exact backend's gathered step tallies
        # LUT-saturation / pre-range events into it from intermediates it
        # materializes anyway (zero extra FP work, byte-identical output).
        # The jit/pallas dispatches are never touched — monitored runs on
        # those backends call :meth:`tally_numeric_events` instead.
        self.numeric_events = None
        self._resident_step = None
        if backend == "exact":
            self._step = self._step_exact
        elif backend == "jit":
            self._jnp_arrs = self.sw.arrays(jnp)
            if self.device is not None:
                self._jnp_arrs = {k: jax.device_put(v, self.device)
                                  for k, v in self._jnp_arrs.items()}
            self._resident_step = self._build_jit_resident()
            self._step = self._build_jit()
        else:
            from .kernel import make_fastgrnn_step
            self._pallas_step = make_fastgrnn_step(
                self.sw, hp=HP, interpret=interpret, mxu=self.mxu)
            self._step = self._step_pallas
            self._resident_step = self._build_pallas_resident()
        # device-side reset: jitted masked zero (no host h round-trip)
        self._reset_resident = jax.jit(
            lambda h, m: jnp.where(m[:, None], jnp.float32(0.0), h))

    # -- state management ---------------------------------------------------
    @property
    def hidden_dim(self) -> int:
        return self.sw.hidden_dim

    @property
    def input_dim(self) -> int:
        return self.sw.input_dim

    def init_state(self, n_slots: int) -> np.ndarray:
        return np.zeros((n_slots, self.sw.hidden_dim), np.float32)

    def reset(self, h: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Zero the hidden state of every slot whose mask bit is set."""
        return np.where(np.asarray(mask)[:, None], np.float32(0.0),
                        np.asarray(h)).astype(np.float32)

    def head_logits(self, h: np.ndarray) -> np.ndarray:
        """Classifier logits for every slot state, (S, H) -> (S, C), via the
        fixed-order f32 head matvec (bit-identical to qruntime.run_window)."""
        return qstep.logits_batched(np, self._np_arrs, self.sw,
                                    np.asarray(h, np.float32))

    # -- device-resident state (jit/pallas backends) ------------------------
    # The streaming/fleet engines keep the hidden-state slot table as a jax
    # device array between ticks: ``step_resident`` advances it with an
    # async dispatch (steady-state ticks move zero h bytes across the
    # host/device boundary), and
    # the row-level accessors below pull/patch only the rows the host
    # actually touches (emission, trajectory taps, snapshots, migration).
    # Every boundary crossing is booked in ``self.transfers``.

    @property
    def supports_device_state(self) -> bool:
        return self.backend != "exact"

    @property
    def device_state_profitable(self) -> bool:
        """Default-on policy for device residency (config ``"auto"``):
        the backend must support it AND the topology must offer real
        device parallelism.  On a single host-platform CPU "device" the
        resident path buys no concurrency (same cores either way) while
        paying the async-dispatch sync and bookkeeping — measured ~16%
        of a fused 1024-slot tick — so "auto" keeps the bit-identical
        host-staged path there and goes resident only on a real
        accelerator or a multi-device topology."""
        return self.supports_device_state and (
            jax.default_backend() != "cpu" or len(jax.devices()) > 1)

    def init_state_device(self, n_slots: int):
        """Zero-initialized (S, H) resident state (created on device — no
        host upload to account)."""
        z = jnp.zeros((n_slots, self.sw.hidden_dim), jnp.float32)
        return z if self.device is None else jax.device_put(z, self.device)

    def to_device(self, h: np.ndarray):
        """Upload a host (S, H) state table (booked as h-state h2d)."""
        h = np.ascontiguousarray(h, np.float32)
        self.transfers.h2d(h.nbytes, state=True)
        dev = jnp.asarray(h) if self.device is None \
            else jax.device_put(h, self.device)
        return dev

    def to_host(self, h_dev) -> np.ndarray:
        """Download the full resident table (snapshot/debug path)."""
        out = np.array(h_dev, np.float32)
        self.transfers.d2h(out.nbytes, state=True)
        return out

    def rows_to_host(self, h_dev, rows) -> np.ndarray:
        """Pull only ``rows`` of the resident state to host (emission,
        taps, lazy snapshots) — a (k, H) d2h instead of the full table."""
        rows = np.asarray(rows)
        out = np.array(h_dev[rows], np.float32)
        self.transfers.d2h(out.nbytes, state=True)
        return out

    def set_rows_device(self, h_dev, rows, values: np.ndarray):
        """Patch ``rows`` of the resident state with host values (migration
        restore) — a (k, H) h2d instead of re-uploading the table."""
        values = np.ascontiguousarray(values, np.float32)
        self.transfers.h2d(values.nbytes, state=True)
        return h_dev.at[np.asarray(rows)].set(values)

    def reset_device(self, h_dev, mask: np.ndarray):
        """Device-side :meth:`reset` — only the (S,) mask crosses h2d."""
        mask = np.asarray(mask, bool)
        self.transfers.h2d(mask.nbytes)
        if self.device is not None:
            mask = jax.device_put(mask, self.device)
        return self._reset_resident(h_dev, mask)

    def concat_device(self, parts):
        """Device-side concat of per-shard h views (fused-tick fallback
        when a shard rebound its state; no boundary crossing)."""
        return jnp.concatenate(parts, axis=0)

    def step_resident(self, h_dev, x: np.ndarray, active: np.ndarray):
        """One masked batched step over the resident state.  Returns the
        NEW device array immediately (async jax dispatch — the caller
        decides when to block); callers must treat ``h_dev`` as consumed
        and adopt the returned array (keeps the contract donation-ready
        for accelerators where donation pays — on CPU it measurably
        doesn't, see ``_build_jit_resident``).  Only x and the active
        mask cross h2d; h never touches the host."""
        x = np.asarray(x, np.float32)
        active = np.asarray(active, bool)
        self.transfers.h2d(x.nbytes + active.nbytes)
        if self.device is not None:
            x = jax.device_put(x, self.device)
            active = jax.device_put(active, self.device)
        return self._resident_step(h_dev, x, active)

    def _build_jit_resident(self):
        # Deliberately NOT donate_argnums=0: buffer donation makes XLA's
        # CPU executable ~3x slower for this kernel (measured 0.20 ms vs
        # 0.066 ms per 1024-row step) AND changes its fusion by ~1 ulp,
        # so donating would cost both throughput and the host-vs-device
        # bit-identity contract.  The resident path doesn't need it for
        # zero-copy — h stays on device either way; donation would only
        # save the output allocation.
        arrs, sw = self._jnp_arrs, self.sw

        @jax.jit
        def f(h, x, active):
            h_new = qstep.step_batched(jnp, arrs, sw, h, x)
            return jnp.where(active[:, None], h_new, h)

        return f

    def _build_pallas_resident(self):
        # Deliberately NOT wrapped in jax.jit: fusing the pad/slice into
        # the kernel's jit trace changes XLA's FMA contraction per batch
        # shape (~1 ulp between a 16-row dispatch and two 8-row ones),
        # which breaks the fleet's shard-count-invariant bit-identity.
        # Eager pads materialize the exact padded operands and the direct
        # pallas_call is batch-shape-stable, so this path is bitwise equal
        # to the host-staged ``_step_pallas`` at every batch size.  The
        # ops still dispatch asynchronously; the trade is losing h-buffer
        # donation (the pallas output allocates regardless).
        pstep = self._pallas_step
        H, d = self.sw.hidden_dim, self.sw.input_dim

        def f(h, x, active):
            S = h.shape[0]
            sp = -S % B_TILE
            h_p = jnp.pad(h, ((0, sp), (0, HP - H)))
            x_p = jnp.pad(x, ((0, sp), (0, HP - d)))
            m_p = jnp.pad(active.astype(jnp.int32), (0, sp))
            return pstep(x_p, h_p, m_p)[:S, :H]

        return f

    def roofline(self, stream_steps_per_sec: float) -> dict:
        """Achieved-vs-peak for the batched single step against the
        ``launch/roofline.py`` hardware model (TPU v5e), at a measured
        aggregate stream-step rate.  ``model`` counts the real (H, d)
        cell's FLOPs; ``padded`` counts what the 128-lane MXU layout
        actually issues — the gap is the padding tax the MXU trade
        accepts to hit the systolic array."""
        from repro.launch import roofline as rl
        sw = self.sw
        H, d = sw.hidden_dim, sw.input_dim
        if sw.low_rank:
            rw, ru = sw.w["W1"].shape[1], sw.w["U1"].shape[1]
            mm = 2 * (d * rw + H * rw + H * ru + H * ru)
        else:
            mm = 2 * H * (d + H)
        gates = 10 * H                       # gate combine + LUT indexing
        flops = mm + gates
        padded = 2 * 2 * HP * HP + 10 * HP   # two (hp, hp) contractions
        # steady-state HBM traffic per stream-step: x in, h in + out
        # (weights/LUTs are VMEM-resident for the whole dispatch)
        bytes_per_step = 4 * (d + 2 * H)
        achieved = flops * float(stream_steps_per_sec)
        return {
            "backend": self.backend,
            "mxu": self.mxu,
            "model_flops_per_stream_step": int(flops),
            "padded_flops_per_stream_step": int(padded),
            "hbm_bytes_per_stream_step": int(bytes_per_step),
            "stream_steps_per_sec": float(stream_steps_per_sec),
            "achieved_gflops": round(achieved / 1e9, 4),
            "peak_fraction": achieved / rl.PEAK_FLOPS,
            "memory_bound_stream_steps_per_sec": rl.HBM_BW / bytes_per_step,
            "peak_flops": rl.PEAK_FLOPS,
            "hbm_bw_bytes_per_sec": rl.HBM_BW,
        }

    # -- one tick -----------------------------------------------------------
    def step(self, h, x, active):
        """h: (S, H) f32, x: (S, d) f32, active: (S,) bool -> h_new (S, H)
        as a NumPy array.  Slots with ``active=False`` keep their hidden
        state bit-for-bit.  Logits are NOT computed here — the engine only
        needs them at emission time; call :meth:`head_logits` on the
        emitting rows."""
        return self._step(np.asarray(h, np.float32),
                          np.asarray(x, np.float32),
                          np.asarray(active, bool))

    def _step_exact(self, h, x, active):
        h_new = qstep.step_batched(np, self._np_arrs, self.sw, h, x)
        return np.where(active[:, None], h_new, h).astype(np.float32)

    # -- scheduler/program adapter ------------------------------------------
    def step_rows(self, h, x, active, rows=None):
        """Slot-program adapter for ``serve/scheduler.SlotScheduler``
        consumers: advance exactly the slots listed in ``rows`` (the
        precomputed ``np.nonzero(active)[0]``; derived here if omitted).

        The exact backend computes *only* those rows — ``step_batched`` is
        row-independent (one fixed-order f32 matvec chain per row), so the
        gathered computation is bit-identical to the masked full-batch step
        while skipping idle slots entirely (partial-occupancy ticks no
        longer pay for the whole slot table).  The jit/pallas backends keep
        the fixed-shape masked step: a varying row count would retrace /
        repad every tick, costing more than the skipped rows save."""
        if self.backend != "exact":
            # the masked full-batch step never needs the row list — skip
            # the nonzero scan entirely (it is measurable at 100k+ slots)
            return self._step(np.asarray(h, np.float32),
                              np.asarray(x, np.float32),
                              np.asarray(active, bool))
        if rows is None:
            rows = np.nonzero(active)[0]
        if rows.size == 0:
            return np.asarray(h, np.float32)
        h = np.asarray(h, np.float32).copy()
        h[rows] = qstep.step_batched(np, self._np_arrs, self.sw,
                                     h[rows], np.asarray(x, np.float32)[rows],
                                     events=self.numeric_events)
        return h

    def tally_numeric_events(self, h, x, rows) -> None:
        """Numeric-health tallies for the jit/pallas backends: recompute
        the advanced rows' step on the host NumPy path purely to observe
        its intermediates (``repro.obs.numerics``), discarding the result.
        The accelerated dispatch itself is never modified, so monitored
        and unmonitored runs stay byte-identical by construction; the
        recompute cost is the price of watching an opaque executable and
        is why monitoring defaults off.  Exact-backend callers never need
        this — ``step_rows`` tallies inline for free."""
        if self.numeric_events is None or rows is None or len(rows) == 0:
            return
        qstep.step_batched(np, self._np_arrs, self.sw,
                           np.asarray(h, np.float32)[np.asarray(rows)],
                           np.asarray(x, np.float32)[np.asarray(rows)],
                           events=self.numeric_events)

    def _build_jit(self):
        # the SAME executable as the resident path — any compilation
        # difference (donation, extra wrapping) changes XLA's fusion
        # choices by ~1 ulp and would break host-vs-device bit-identity.
        # This host-staged path round-trips the full h table every tick
        # — booked so stats()/fleet_bench can show the contrast with the
        # zero-h-copy resident step.
        dev, ledger, f = self.device, self.transfers, self._resident_step

        def run(h, x, active):
            ledger.h2d(x.nbytes + active.nbytes)
            ledger.h2d(h.nbytes, state=True)
            if dev is not None:
                h, x, active = (jax.device_put(h, dev),
                                jax.device_put(x, dev),
                                jax.device_put(active, dev))
            out = np.asarray(f(h, x, active))
            ledger.d2h(out.nbytes, state=True)
            return out

        return run

    def _step_pallas(self, h, x, active):
        S, H = h.shape
        sp = -S % B_TILE
        h_p = np.zeros((S + sp, HP), np.float32)
        h_p[:S, :H] = h
        x_p = np.zeros((S + sp, HP), np.float32)
        x_p[:S, :x.shape[1]] = x
        m_p = np.zeros((S + sp,), np.int32)
        m_p[:S] = active
        # host-staged path: full padded h round-trip per tick (cf. the
        # zero-h-copy device-resident step_resident)
        self.transfers.h2d(x_p.nbytes + m_p.nbytes)
        self.transfers.h2d(h_p.nbytes, state=True)
        if self.device is not None:
            args = (jax.device_put(x_p, self.device),
                    jax.device_put(h_p, self.device),
                    jax.device_put(m_p, self.device))
        else:
            args = (jnp.asarray(x_p), jnp.asarray(h_p), jnp.asarray(m_p))
        h_new = self._pallas_step(*args)
        out = np.asarray(h_new)[:S, :H]
        self.transfers.d2h(out.nbytes, state=True)
        return out
