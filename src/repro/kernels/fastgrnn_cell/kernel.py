"""Pallas TPU kernel: fused FastGRNN full-window scan (paper Eq. 1-3 +
Sec. III-E LUT activations).

MCU -> TPU adaptation (DESIGN.md Sec. 2): on the MSP430 the weights live in
Flash and the ~300 B working set in SRAM for the whole 128-sample window.
Here the low-rank factors, biases, both LUTs AND the hidden state stay
resident in VMEM for the entire window — one HBM read of x, one write of
the trajectory, zero weight re-fetches, and the per-step dispatch overhead
of 128 separate cell calls collapses into one kernel launch (the TPU
analogue of the paper's 30.5x LUT win being about *eliminating per-step
overhead*, not raw FLOPs).

Grid: one program per batch tile; fori_loop over T inside the kernel.
Dims are padded to the (8,128) float32 tile by ops.py; the real H=16,d=3
cell uses a (B_tile, 128)-padded layout where lanes beyond H/d are zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import qstep

B_TILE = 8


def _cell_kernel(sig_lut_ref, tanh_lut_ref, x_ref, w_ref, u_ref,
                 bz_ref, bh_ref, scal_ref, h_ref, traj_ref,
                 *, T: int, lo: float, hi: float):
    """x: (T, B_TILE, Dp); w: (Dp, Hp) = W^T (pre-multiplied low-rank);
    u: (Hp, Hp) = U^T; scal: (2,) [zeta, nu] post-sigmoid; outputs:
    h (B_TILE, Hp), traj (T, B_TILE, Hp)."""
    size = sig_lut_ref.shape[0]
    bw = (hi - lo) / size
    inv_bw = 1.0 / bw

    def lut(table, v):
        idx = jnp.clip(((v - lo) * inv_bw).astype(jnp.int32), 0, size - 1)
        y = jnp.take(table, idx)
        return jnp.where(v >= hi, table[size - 1],
                         jnp.where(v <= lo, table[0], y))

    w = w_ref[...]
    u = u_ref[...]
    b_z = bz_ref[...]
    b_h = bh_ref[...]
    zeta = scal_ref[0]
    nu = scal_ref[1]
    sig_t = sig_lut_ref[...]
    tanh_t = tanh_lut_ref[...]

    def step(t, h):
        x_t = x_ref[t]                                   # (B_TILE, Dp)
        pre = jnp.dot(x_t, w, preferred_element_type=jnp.float32) \
            + jnp.dot(h, u, preferred_element_type=jnp.float32)
        z = lut(sig_t, pre + b_z)
        h_tilde = lut(tanh_t, pre + b_h)
        h_new = (zeta * (1.0 - z) + nu) * h_tilde + z * h
        traj_ref[t] = h_new
        return h_new

    h = jnp.zeros_like(h_ref)
    h = jax.lax.fori_loop(0, T, step, h)
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("T", "lo", "hi", "interpret"))
def fastgrnn_window(sig_lut, tanh_lut, x, w_t, u_t, b_z, b_h, scal,
                    *, T: int, lo: float = -8.0, hi: float = 8.0,
                    interpret: bool = True):
    """x: (T, B, Dp); w_t: (Dp, Hp); u_t: (Hp, Hp); b_z/b_h: (Hp,);
    scal: (2,).  B % B_TILE == 0 (ops.py pads).  Returns (h, traj)."""
    Tn, B, Dp = x.shape
    Hp = w_t.shape[1]
    grid = (B // B_TILE,)
    return pl.pallas_call(
        functools.partial(_cell_kernel, T=T, lo=lo, hi=hi),
        grid=grid,
        in_specs=[
            pl.BlockSpec((sig_lut.shape[0],), lambda b: (0,)),
            pl.BlockSpec((tanh_lut.shape[0],), lambda b: (0,)),
            pl.BlockSpec((Tn, B_TILE, Dp), lambda b: (0, b, 0)),
            pl.BlockSpec((Dp, Hp), lambda b: (0, 0)),
            pl.BlockSpec((Hp, Hp), lambda b: (0, 0)),
            pl.BlockSpec((Hp,), lambda b: (0,)),
            pl.BlockSpec((Hp,), lambda b: (0,)),
            pl.BlockSpec((2,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((B_TILE, Hp), lambda b: (b, 0)),
            pl.BlockSpec((Tn, B_TILE, Hp), lambda b: (0, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hp), jnp.float32),
            jax.ShapeDtypeStruct((Tn, B, Hp), jnp.float32),
        ],
        interpret=interpret,
    )(sig_lut, tanh_lut, x, w_t, u_t, b_z, b_h, scal)


# ---------------------------------------------------------------------------
# Batched single-step kernel (multi-stream streaming inference)
# ---------------------------------------------------------------------------
# One FastGRNN step for a whole batch of independent streams: the serving
# analogue of a fleet of deployed sensors, each slot carrying its own hidden
# state.  Unlike the full-window scan above, weights arrive as *raw int16
# Q15* and are dequantized on use inside the kernel (w = f32(Wq) * scale) —
# the paper's Appendix-B recipe executed in VMEM, so HBM traffic for the
# weight stream is halved vs f32 storage.  The body reuses the generic
# qstep math (fixed ascending-j matvec, nearest-bucket LUT), sliced to the
# real dims so the op order per stream matches core/qruntime.py exactly;
# padded lanes never enter the accumulation chain.


def _q15_step_kernel(sig_ref, tanh_ref, x_ref, h_ref, mask_ref,
                     *refs, sw: "qstep.StepWeights", d: int, H: int):
    """x: (B_TILE, Dp); h: (B_TILE, Hp); mask: (B_TILE,) int32;
    refs: int16 weight refs (W|W1,W2,U|U1,U2) then b_z, b_h, out."""
    names = qstep.LOW_RANK_NAMES if sw.low_rank else qstep.FULL_RANK_NAMES
    w_refs, (bz_ref, bh_ref, out_ref) = refs[:len(names)], refs[len(names):]
    real = {"W": (H, d), "U": (H, H),
            "W1": sw.w.get("W1", np.zeros((0, 0))).shape,
            "W2": sw.w.get("W2", np.zeros((0, 0))).shape,
            "U1": sw.w.get("U1", np.zeros((0, 0))).shape,
            "U2": sw.w.get("U2", np.zeros((0, 0))).shape}
    arrs = {}
    for n, ref in zip(names, w_refs):
        r, c = real[n]
        # dequantize-on-use (Appendix B), sliced to real dims so the
        # qstep matvec loops never touch a padded column
        arrs[n] = ref[...][:r, :c].astype(jnp.float32) * np.float32(sw.scales[n])
    arrs.update(b_z=bz_ref[...][:H], b_h=bh_ref[...][:H],
                sig_lut=sig_ref[...], tanh_lut=tanh_ref[...])

    x = x_ref[...][:, :d]
    h = h_ref[...][:, :H]
    h_new = qstep.step_batched(jnp, arrs, sw, h, x)
    h_new = jnp.where(mask_ref[...][:, None] != 0, h_new, h)
    out_ref[...] = jnp.pad(h_new, ((0, 0), (0, out_ref.shape[1] - H)))


def make_fastgrnn_step(sw: "qstep.StepWeights", *, hp: int = 128,
                       interpret: bool = True):
    """Build the batched single-step callable: pads the int16 weight
    tensors, biases and LUTs to device layout ONCE (they are deployment
    constants — this runs on every 50 Hz tick, so per-call re-padding
    would dominate) and caches one ``pl.pallas_call`` per slot count.

    Returns ``step(x, h, mask) -> h_new``: x (S, Dp), h (S, Hp), mask (S,)
    int32, S % B_TILE == 0 (ops.py pads).  Lanes >= H of h_new are zero."""
    d, H = sw.input_dim, sw.hidden_dim
    names = qstep.LOW_RANK_NAMES if sw.low_rank else qstep.FULL_RANK_NAMES

    def pad2(a):
        a = np.asarray(a)
        return jnp.asarray(np.pad(a, ((0, hp - a.shape[0]), (0, hp - a.shape[1]))))

    def pad1(a):
        a = np.asarray(a, np.float32)
        return jnp.asarray(np.pad(a, (0, hp - a.shape[0])))

    consts = ([jnp.asarray(sw.sig_lut), jnp.asarray(sw.tanh_lut)],
              [pad2(sw.q[n]) for n in names],
              [pad1(sw.b_z), pad1(sw.b_h)])
    kernel = functools.partial(_q15_step_kernel, sw=sw, d=d, H=H)
    calls: dict[tuple[int, int], "object"] = {}

    def step(x, h, mask):
        S, dp = x.shape
        key = (S, dp)
        if key not in calls:
            full = lambda shape: pl.BlockSpec(shape, lambda b: (0,) * len(shape))
            calls[key] = pl.pallas_call(
                kernel,
                grid=(S // B_TILE,),
                in_specs=[
                    full((qstep.LUT_SIZE,)), full((qstep.LUT_SIZE,)),
                    pl.BlockSpec((B_TILE, dp), lambda b: (b, 0)),
                    pl.BlockSpec((B_TILE, hp), lambda b: (b, 0)),
                    pl.BlockSpec((B_TILE,), lambda b: (b,)),
                    *[full((hp, hp)) for _ in names],
                    full((hp,)), full((hp,)),
                ],
                out_specs=pl.BlockSpec((B_TILE, hp), lambda b: (b, 0)),
                out_shape=jax.ShapeDtypeStruct((S, hp), jnp.float32),
                interpret=interpret,
            )
        luts, w_in, biases = consts
        return calls[key](*luts, x, h, mask, *w_in, *biases)

    return step
