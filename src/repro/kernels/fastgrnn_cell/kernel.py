"""Pallas TPU kernel: fused FastGRNN full-window scan (paper Eq. 1-3 +
Sec. III-E LUT activations).

MCU -> TPU adaptation (DESIGN.md Sec. 2): on the MSP430 the weights live in
Flash and the ~300 B working set in SRAM for the whole 128-sample window.
Here the low-rank factors, biases, both LUTs AND the hidden state stay
resident in VMEM for the entire window — one HBM read of x, one write of
the trajectory, zero weight re-fetches, and the per-step dispatch overhead
of 128 separate cell calls collapses into one kernel launch (the TPU
analogue of the paper's 30.5x LUT win being about *eliminating per-step
overhead*, not raw FLOPs).

Grid: one program per batch tile; fori_loop over T inside the kernel.
Dims are padded to the (8,128) float32 tile by ops.py; the real H=16,d=3
cell uses a (B_tile, 128)-padded layout where lanes beyond H/d are zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import qstep

B_TILE = 8


def _cell_kernel(sig_lut_ref, tanh_lut_ref, x_ref, w_ref, u_ref,
                 bz_ref, bh_ref, scal_ref, h_ref, traj_ref,
                 *, T: int, lo: float, hi: float):
    """x: (T, B_TILE, Dp); w: (Dp, Hp) = W^T (pre-multiplied low-rank);
    u: (Hp, Hp) = U^T; scal: (2,) [zeta, nu] post-sigmoid; outputs:
    h (B_TILE, Hp), traj (T, B_TILE, Hp)."""
    size = sig_lut_ref.shape[0]
    bw = (hi - lo) / size
    inv_bw = 1.0 / bw

    def lut(table, v):
        idx = jnp.clip(((v - lo) * inv_bw).astype(jnp.int32), 0, size - 1)
        y = jnp.take(table, idx)
        return jnp.where(v >= hi, table[size - 1],
                         jnp.where(v <= lo, table[0], y))

    w = w_ref[...]
    u = u_ref[...]
    b_z = bz_ref[...]
    b_h = bh_ref[...]
    zeta = scal_ref[0]
    nu = scal_ref[1]
    sig_t = sig_lut_ref[...]
    tanh_t = tanh_lut_ref[...]

    def step(t, h):
        x_t = x_ref[t]                                   # (B_TILE, Dp)
        pre = jnp.dot(x_t, w, preferred_element_type=jnp.float32) \
            + jnp.dot(h, u, preferred_element_type=jnp.float32)
        z = lut(sig_t, pre + b_z)
        h_tilde = lut(tanh_t, pre + b_h)
        h_new = (zeta * (1.0 - z) + nu) * h_tilde + z * h
        traj_ref[t] = h_new
        return h_new

    h = jnp.zeros_like(h_ref)
    h = jax.lax.fori_loop(0, T, step, h)
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("T", "lo", "hi", "interpret"))  # detlint: ignore[det-jit-pallas] fixed window shapes (ops.py pads pre-call); resident path builds its own eager-pad wrapper
def fastgrnn_window(sig_lut, tanh_lut, x, w_t, u_t, b_z, b_h, scal,
                    *, T: int, lo: float = -8.0, hi: float = 8.0,
                    interpret: bool = True):
    """x: (T, B, Dp); w_t: (Dp, Hp); u_t: (Hp, Hp); b_z/b_h: (Hp,);
    scal: (2,).  B % B_TILE == 0 (ops.py pads).  Returns (h, traj)."""
    Tn, B, Dp = x.shape
    Hp = w_t.shape[1]
    grid = (B // B_TILE,)
    return pl.pallas_call(
        functools.partial(_cell_kernel, T=T, lo=lo, hi=hi),
        grid=grid,
        in_specs=[
            pl.BlockSpec((sig_lut.shape[0],), lambda b: (0,)),
            pl.BlockSpec((tanh_lut.shape[0],), lambda b: (0,)),
            pl.BlockSpec((Tn, B_TILE, Dp), lambda b: (0, b, 0)),
            pl.BlockSpec((Dp, Hp), lambda b: (0, 0)),
            pl.BlockSpec((Hp, Hp), lambda b: (0, 0)),
            pl.BlockSpec((Hp,), lambda b: (0,)),
            pl.BlockSpec((Hp,), lambda b: (0,)),
            pl.BlockSpec((2,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((B_TILE, Hp), lambda b: (b, 0)),
            pl.BlockSpec((Tn, B_TILE, Hp), lambda b: (0, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hp), jnp.float32),
            jax.ShapeDtypeStruct((Tn, B, Hp), jnp.float32),
        ],
        interpret=interpret,
    )(sig_lut, tanh_lut, x, w_t, u_t, b_z, b_h, scal)


# ---------------------------------------------------------------------------
# Batched single-step kernel (multi-stream streaming inference)
# ---------------------------------------------------------------------------
# One FastGRNN step for a whole batch of independent streams: the serving
# analogue of a fleet of deployed sensors, each slot carrying its own hidden
# state.  Unlike the full-window scan above, weights arrive as *raw int16
# Q15* and are dequantized on use inside the kernel (w = f32(Wq) * scale) —
# the paper's Appendix-B recipe executed in VMEM, so HBM traffic for the
# weight stream is halved vs f32 storage.  The body reuses the generic
# qstep math (fixed ascending-j matvec, nearest-bucket LUT), sliced to the
# real dims so the op order per stream matches core/qruntime.py exactly;
# padded lanes never enter the accumulation chain.


def _q15_step_kernel(sig_ref, tanh_ref, x_ref, h_ref, mask_ref,
                     *refs, sw: "qstep.StepWeights", d: int, H: int):
    """x: (B_TILE, Dp); h: (B_TILE, Hp); mask: (B_TILE,) int32;
    refs: int16 weight refs (W|W1,W2,U|U1,U2) then b_z, b_h, out."""
    names = qstep.LOW_RANK_NAMES if sw.low_rank else qstep.FULL_RANK_NAMES
    w_refs, (bz_ref, bh_ref, out_ref) = refs[:len(names)], refs[len(names):]
    real = {"W": (H, d), "U": (H, H),
            "W1": sw.w.get("W1", np.zeros((0, 0))).shape,
            "W2": sw.w.get("W2", np.zeros((0, 0))).shape,
            "U1": sw.w.get("U1", np.zeros((0, 0))).shape,
            "U2": sw.w.get("U2", np.zeros((0, 0))).shape}
    arrs = {}
    for n, ref in zip(names, w_refs):
        r, c = real[n]
        # dequantize-on-use (Appendix B), sliced to real dims so the
        # qstep matvec loops never touch a padded column
        arrs[n] = ref[...][:r, :c].astype(jnp.float32) * np.float32(sw.scales[n])
    arrs.update(b_z=bz_ref[...][:H], b_h=bh_ref[...][:H],
                sig_lut=sig_ref[...], tanh_lut=tanh_ref[...])

    x = x_ref[...][:, :d]
    h = h_ref[...][:, :H]
    h_new = qstep.step_batched(jnp, arrs, sw, h, x)
    h_new = jnp.where(mask_ref[...][:, None] != 0, h_new, h)
    out_ref[...] = jnp.pad(h_new, ((0, 0), (0, out_ref.shape[1] - H)))


def _q15_step_kernel_mxu(sig_ref, tanh_ref, x_ref, h_ref, mask_ref,
                         w_ref, u_ref, bz_ref, bh_ref, out_ref,
                         *, zeta: float, nu: float):
    """MXU-shaped variant of the batched single step: x/h stay in the full
    128-lane padded layout and the two projections run as real
    (B_TILE, 128) x (128, 128) contractions — one MXU pass each on TPU —
    against *pre-dequantized, pre-multiplied* effective W^T/U^T (f32).

    Padded lanes are inert by construction: effective-weight rows/columns
    beyond (H, d) are zero, so ``pre`` is 0 there; the gate combine then
    yields ``z * h = 0.5-ish * 0 = 0`` for padded h lanes (h enters padded
    as zero every call — the resident wrapper in ops.py re-pads from the
    (S, H) state), and the caller slices back to ``[:S, :H]``.  Numerics:
    the MXU dot sums in hardware order, so hidden states drift from the
    bit-exact reference like the jit backend does (~1e-9/step); argmax
    predictions agree (gated in tests/test_device_fleet.py)."""
    size = sig_ref.shape[0]
    lo, hi = qstep.INPUT_MIN, qstep.INPUT_MAX
    inv_bw = size / (hi - lo)

    def lut(table, v):
        idx = jnp.clip(((v - lo) * inv_bw).astype(jnp.int32), 0, size - 1)
        y = jnp.take(table, idx)
        return jnp.where(v >= hi, table[size - 1],
                         jnp.where(v <= lo, table[0], y))

    h = h_ref[...]
    pre = jnp.dot(x_ref[...], w_ref[...],
                  preferred_element_type=jnp.float32) \
        + jnp.dot(h, u_ref[...], preferred_element_type=jnp.float32)
    z = lut(sig_ref[...], pre + bz_ref[...])
    h_tilde = lut(tanh_ref[...], pre + bh_ref[...])
    h_new = (zeta * (1.0 - z) + nu) * h_tilde + z * h
    out_ref[...] = jnp.where(mask_ref[...][:, None] != 0, h_new, h)


def make_fastgrnn_step(sw: "qstep.StepWeights", *, hp: int = 128,
                       interpret: bool = True, mxu: bool = False):
    """Build the batched single-step callable: pads the weight tensors,
    biases and LUTs to device layout ONCE (they are deployment constants —
    this runs on every 50 Hz tick, so per-call re-padding would dominate)
    and caches one ``pl.pallas_call`` per slot count.

    ``mxu=False`` (default): int16 Q15 weights dequantized on use, sliced
    to real dims, qstep's fixed-order matvec loops — the layout whose op
    order matches the scalar reference.  ``mxu=True``: the 128-lane padded
    layout — effective W^T/U^T pre-multiplied to dense f32 (hp, hp) and the
    projections lowered as (B_TILE, hp) x (hp, hp) MXU contractions
    (achieved-vs-peak reported via ``Q15StreamStep.roofline``).

    Returns ``step(x, h, mask) -> h_new``: x (S, Dp), h (S, Hp), mask (S,)
    int32, S % B_TILE == 0 (ops.py pads).  Lanes >= H of h_new are zero."""
    d, H = sw.input_dim, sw.hidden_dim
    names = qstep.LOW_RANK_NAMES if sw.low_rank else qstep.FULL_RANK_NAMES

    def pad2(a):
        a = np.asarray(a)
        return jnp.asarray(np.pad(a, ((0, hp - a.shape[0]), (0, hp - a.shape[1]))))

    def pad1(a):
        a = np.asarray(a, np.float32)
        return jnp.asarray(np.pad(a, (0, hp - a.shape[0])))

    if mxu:
        w_eff = (sw.w["W1"] @ sw.w["W2"].T if sw.low_rank
                 else sw.w["W"]).astype(np.float32)          # (H, d)
        u_eff = (sw.w["U1"] @ sw.w["U2"].T if sw.low_rank
                 else sw.w["U"]).astype(np.float32)          # (H, H)
        weight_ops = [pad2(w_eff.T), pad2(u_eff.T)]          # (hp, hp) f32
        kernel = functools.partial(_q15_step_kernel_mxu,
                                   zeta=float(sw.zeta), nu=float(sw.nu))
    else:
        weight_ops = [pad2(sw.q[n]) for n in names]          # int16 Q15
        kernel = functools.partial(_q15_step_kernel, sw=sw, d=d, H=H)
    consts = ([jnp.asarray(sw.sig_lut), jnp.asarray(sw.tanh_lut)],
              weight_ops,
              [pad1(sw.b_z), pad1(sw.b_h)])
    calls: dict[tuple[int, int], "object"] = {}

    def step(x, h, mask):
        S, dp = x.shape
        key = (S, dp)
        if key not in calls:
            full = lambda shape: pl.BlockSpec(shape, lambda b: (0,) * len(shape))
            calls[key] = pl.pallas_call(
                kernel,
                grid=(S // B_TILE,),
                in_specs=[
                    full((qstep.LUT_SIZE,)), full((qstep.LUT_SIZE,)),
                    pl.BlockSpec((B_TILE, dp), lambda b: (b, 0)),
                    pl.BlockSpec((B_TILE, hp), lambda b: (b, 0)),
                    pl.BlockSpec((B_TILE,), lambda b: (b,)),
                    *[full((hp, hp)) for _ in weight_ops],
                    full((hp,)), full((hp,)),
                ],
                out_specs=pl.BlockSpec((B_TILE, hp), lambda b: (b, 0)),
                out_shape=jax.ShapeDtypeStruct((S, hp), jnp.float32),
                interpret=interpret,
            )
        luts, w_in, biases = consts
        return calls[key](*luts, x, h, mask, *w_in, *biases)

    return step
