"""Pallas TPU kernel: fused FastGRNN full-window scan (paper Eq. 1-3 +
Sec. III-E LUT activations).

MCU -> TPU adaptation (DESIGN.md Sec. 2): on the MSP430 the weights live in
Flash and the ~300 B working set in SRAM for the whole 128-sample window.
Here the low-rank factors, biases, both LUTs AND the hidden state stay
resident in VMEM for the entire window — one HBM read of x, one write of
the trajectory, zero weight re-fetches, and the per-step dispatch overhead
of 128 separate cell calls collapses into one kernel launch (the TPU
analogue of the paper's 30.5x LUT win being about *eliminating per-step
overhead*, not raw FLOPs).

Grid: one program per batch tile; fori_loop over T inside the kernel.
Dims are padded to the (8,128) float32 tile by ops.py; the real H=16,d=3
cell uses a (B_tile, 128)-padded layout where lanes beyond H/d are zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_TILE = 8


def _cell_kernel(sig_lut_ref, tanh_lut_ref, x_ref, w_ref, u_ref,
                 bz_ref, bh_ref, scal_ref, h_ref, traj_ref,
                 *, T: int, lo: float, hi: float):
    """x: (T, B_TILE, Dp); w: (Dp, Hp) = W^T (pre-multiplied low-rank);
    u: (Hp, Hp) = U^T; scal: (2,) [zeta, nu] post-sigmoid; outputs:
    h (B_TILE, Hp), traj (T, B_TILE, Hp)."""
    size = sig_lut_ref.shape[0]
    bw = (hi - lo) / size
    inv_bw = 1.0 / bw

    def lut(table, v):
        idx = jnp.clip(((v - lo) * inv_bw).astype(jnp.int32), 0, size - 1)
        y = jnp.take(table, idx)
        return jnp.where(v >= hi, table[size - 1],
                         jnp.where(v <= lo, table[0], y))

    w = w_ref[...]
    u = u_ref[...]
    b_z = bz_ref[...]
    b_h = bh_ref[...]
    zeta = scal_ref[0]
    nu = scal_ref[1]
    sig_t = sig_lut_ref[...]
    tanh_t = tanh_lut_ref[...]

    def step(t, h):
        x_t = x_ref[t]                                   # (B_TILE, Dp)
        pre = jnp.dot(x_t, w, preferred_element_type=jnp.float32) \
            + jnp.dot(h, u, preferred_element_type=jnp.float32)
        z = lut(sig_t, pre + b_z)
        h_tilde = lut(tanh_t, pre + b_h)
        h_new = (zeta * (1.0 - z) + nu) * h_tilde + z * h
        traj_ref[t] = h_new
        return h_new

    h = jnp.zeros_like(h_ref)
    h = jax.lax.fori_loop(0, T, step, h)
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("T", "lo", "hi", "interpret"))
def fastgrnn_window(sig_lut, tanh_lut, x, w_t, u_t, b_z, b_h, scal,
                    *, T: int, lo: float = -8.0, hi: float = 8.0,
                    interpret: bool = True):
    """x: (T, B, Dp); w_t: (Dp, Hp); u_t: (Hp, Hp); b_z/b_h: (Hp,);
    scal: (2,).  B % B_TILE == 0 (ops.py pads).  Returns (h, traj)."""
    Tn, B, Dp = x.shape
    Hp = w_t.shape[1]
    grid = (B // B_TILE,)
    return pl.pallas_call(
        functools.partial(_cell_kernel, T=T, lo=lo, hi=hi),
        grid=grid,
        in_specs=[
            pl.BlockSpec((sig_lut.shape[0],), lambda b: (0,)),
            pl.BlockSpec((tanh_lut.shape[0],), lambda b: (0,)),
            pl.BlockSpec((Tn, B_TILE, Dp), lambda b: (0, b, 0)),
            pl.BlockSpec((Dp, Hp), lambda b: (0, 0)),
            pl.BlockSpec((Hp, Hp), lambda b: (0, 0)),
            pl.BlockSpec((Hp,), lambda b: (0,)),
            pl.BlockSpec((Hp,), lambda b: (0,)),
            pl.BlockSpec((2,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((B_TILE, Hp), lambda b: (b, 0)),
            pl.BlockSpec((Tn, B_TILE, Hp), lambda b: (0, b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hp), jnp.float32),
            jax.ShapeDtypeStruct((Tn, B, Hp), jnp.float32),
        ],
        interpret=interpret,
    )(sig_lut, tanh_lut, x, w_t, u_t, b_z, b_h, scal)
