"""Pure-jnp oracle for the fused FastGRNN window kernel: the LUT-activated
cell from core/fastgrnn.py + core/lut.py run over a full window."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import fastgrnn as fg
from repro.core.lut import lut_sigmoid, lut_tanh


def fastgrnn_window_ref(params, xs, *, lut: bool = True, mode: str = "nearest"):
    """xs: (T, B, d) -> final hidden (B, H) + trajectory (T, B, H)."""
    sig = (lambda v: lut_sigmoid(v, mode)) if lut else None
    tnh = (lambda v: lut_tanh(v, mode)) if lut else None
    kw = {}
    if lut:
        kw = {"sigma": sig, "tanh": tnh}
    h, traj = fg.run_sequence(params, xs, return_trajectory=True, **kw)
    return h, traj


def q15_step_batched_ref(qp, h, x, *, act_scales=None, naive_acts=False):
    """Scalar-loop oracle for the batched Q15 single step: one
    ``core/qruntime.QRuntime.step`` call per stream row.  h: (S, H),
    x: (S, d) -> (h_new (S, H), logits (S, C)).  This IS the paper's
    C-equivalent reference, so the exact backend must match it bit-for-bit.
    """
    import numpy as np

    from repro.core.qruntime import QRuntime, _matvec

    rt = QRuntime(qp, act_scales=act_scales, naive_acts=naive_acts)
    h = np.asarray(h, np.float32)
    h_new = np.stack([rt.step(h[b], np.asarray(x[b], np.float32))
                      for b in range(h.shape[0])])
    logits = np.stack([
        rt._store("logits",
                  _matvec(rt._w["head_w"].T, h_new[b]) + rt._head_b)
        for b in range(h.shape[0])])
    return h_new, logits
