"""Pure-jnp oracle for the fused FastGRNN window kernel: the LUT-activated
cell from core/fastgrnn.py + core/lut.py run over a full window."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import fastgrnn as fg
from repro.core.lut import lut_sigmoid, lut_tanh


def fastgrnn_window_ref(params, xs, *, lut: bool = True, mode: str = "nearest"):
    """xs: (T, B, d) -> final hidden (B, H) + trajectory (T, B, H)."""
    sig = (lambda v: lut_sigmoid(v, mode)) if lut else None
    tnh = (lambda v: lut_tanh(v, mode)) if lut else None
    kw = {}
    if lut:
        kw = {"sigma": sig, "tanh": tnh}
    h, traj = fg.run_sequence(params, xs, return_trajectory=True, **kw)
    return h, traj
