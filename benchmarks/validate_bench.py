"""Schema validation for the emitted BENCH_*.json perf artifacts.

    PYTHONPATH=src python -m benchmarks.validate_bench BENCH_*.json

CI's bench-smoke job regenerates the benchmarks in a tiny configuration
and runs this validator over the output, so a refactor that silently
breaks a bench (missing key, NaN/inf throughput, empty results) fails the
build instead of rotting the perf trajectory.

Each known ``benchmark`` kind pins its required top-level keys and, where
the record carries a ``results`` list, the required per-row keys.  Every
numeric value anywhere in the record must be finite.
"""
from __future__ import annotations

import json
import math
import sys

SCHEMAS: dict[str, dict] = {
    "streaming_throughput": {
        "top": ["benchmark", "model", "sample_rate_hz", "window", "host",
                "results"],
        "row": ["backend", "concurrent_streams", "ticks",
                "stream_steps_per_sec", "streams_per_sec", "p50_ms",
                "p99_ms", "realtime_streams_50hz"],
    },
    "serve_continuous_batching": {
        "top": ["benchmark", "model", "slots", "requests", "budgets",
                "host", "results", "speedup_tokens_per_sec"],
        "row": ["mode", "admit_policy", "requests", "tokens", "wall_s",
                "tokens_per_sec", "decode_ticks", "prefills", "scheduler"],
    },
    "deploy_export": {
        "top": ["benchmark", "model", "host", "image", "budgets", "qvm",
                "c_host", "parity", "mcu_cycle_model"],
    },
    # benchmarks/fleet_bench.py: shard-count scaling sweep + the 100k+
    # concurrent-stream capacity point.  `capacity` pins the headline
    # claims (concurrent_streams, realtime_streams_50hz) so the artifact
    # cannot silently drop them.
    "fleet_sharding": {
        "top": ["benchmark", "model", "backend", "placement", "placements",
                "slots_per_shard", "window", "sample_rate_hz", "host",
                "results", "scaling_1_to_max_x", "scaling_by_placement",
                "capacity", "kernel_roofline"],
        "row": ["shards", "placement", "concurrent_streams", "ticks",
                "stream_steps_per_sec", "p50_ms", "p99_ms",
                "realtime_streams_50hz", "scaling_x",
                "scaling_efficiency", "transfers", "zero_copy_h",
                "scheduler"],
        "capacity": ["shards", "slots_per_shard", "placement",
                     "concurrent_streams", "stream_steps_per_sec",
                     "realtime_streams_50hz", "sustained_realtime_50hz",
                     "transfers", "zero_copy_h"],
        # device-residency gate: h-state bytes over the steady window
        # (repro.obs.transfers.TRANSFER_KEYS, per-row under "transfers")
        "kernel_roofline": ["backend", "model_flops_per_stream_step",
                            "padded_flops_per_stream_step",
                            "hbm_bytes_per_stream_step", "achieved_gflops",
                            "peak_fraction",
                            "memory_bound_stream_steps_per_sec"],
    },
    # benchmarks/failover_bench.py: crash/recovery latency for a shard
    # holding `slots_per_shard` streams.  `recovery` pins the headline
    # (p50/p99 unavailability window of a 16k-stream shard crash).
    "fleet_failover": {
        "top": ["benchmark", "model", "backend", "shards",
                "slots_per_shard", "snapshot_every", "samples_per_stream",
                "host", "results", "recovery"],
        "row": ["rep", "streams_recovered", "replayed_samples",
                "wire_bytes", "snapshot_ms", "recovery_ms",
                "recovery_us_per_stream"],
        "recovery": ["streams", "recovery_ms_p50", "recovery_ms_p99",
                     "snapshot_ms_p50", "recovery_us_per_stream_p50",
                     "wire_mb_per_shard"],
    },
    # `python -m repro.compress --report`: one compression-pipeline run.
    # `size` is ModelArtifact.size_report() — per-tensor dense vs
    # CSR-packed bytes at the artifact's true weight width (Q15/Q7).
    "compress_artifact": {
        "top": ["benchmark", "pipeline", "sha256", "artifact_bytes",
                "size", "provenance"],
        "size": ["bits", "weight_bytes_dense", "weight_bytes_packed",
                 "tensors", "passes"],
    },
    # repro.obs.MetricsRegistry.snapshot(): the serving stack's metrics
    # export (written by --metrics-out on streaming_throughput /
    # fleet_bench / serve_demo).  Deep-checked by _check_metrics_snapshot
    # below: log2 bucket ladder, bucket-count conservation, counter
    # non-negativity.
    "metrics_snapshot": {
        "top": ["benchmark", "schema_version", "deterministic",
                "counters", "gauges", "histograms"],
    },
    # `python -m repro.analysis`: the static-analysis gate's report —
    # qlint per-site proven bounds + detlint findings/suppressions.
    # Deep-checked by _check_analysis_report below: per-target and
    # per-site keys, finding/suppression shape, summary consistency.
    "analysis_report": {
        "top": ["benchmark", "schema_version", "qlint", "detlint",
                "summary"],
        "summary": ["findings", "suppressed", "ok"],
    },
    # benchmarks/numerics_bench.py: numeric-health monitor overhead
    # budget, qvm<->C saturation-counter parity (incl. the stress
    # witness), the drift-injection demo, and the static/dynamic
    # saturation cross-check verdict.
    "numerics_health": {
        "top": ["benchmark", "model", "backend", "host", "config",
                "overhead", "budgets", "counter_parity", "drift_demo",
                "crosscheck"],
        "overhead": ["baseline_steps_per_sec", "null_steps_per_sec",
                     "monitored_steps_per_sec", "null_overhead_pct",
                     "monitored_overhead_pct", "monitor_marginal_pct",
                     "measured_noise_pct"],
        "budgets": ["monitored_budget_pct", "monitored_within_budget",
                    "null_budget_pct", "null_within_noise"],
        "counter_parity": ["windows", "stress_gain", "available",
                           "counters_equal", "preds_equal",
                           "stress_counters_equal", "stress_h_next"],
        "drift_demo": ["scales", "drift_scores", "monotone"],
        "crosscheck": ["ok", "violations", "witnessed",
                       "unwitnessed_reachable"],
    },
    # benchmarks/obs_bench.py: telemetry overhead budgets + tick-phase
    # breakdown + deadline-miss rate + flight-recorder byte stability.
    "obs_overhead": {
        "top": ["benchmark", "model", "backend", "window",
                "sample_rate_hz", "host", "config", "baseline", "traced",
                "budgets", "phases", "deadline", "flight_recorder"],
        "baseline": ["concurrent_streams", "ticks",
                     "stream_steps_per_sec", "p50_ms", "p99_ms"],
        "traced": ["concurrent_streams", "ticks", "stream_steps_per_sec",
                   "p50_ms", "p99_ms"],
        "budgets": ["traced_overhead_pct", "traced_budget_pct",
                    "traced_within_budget", "null_budget_pct"],
        "deadline": ["deadline_ms", "concurrent_streams", "miss_ticks",
                     "miss_stream_ticks", "stream_ticks", "miss_rate"],
        "flight_recorder": ["shards", "crashes", "dump_bytes",
                            "byte_stable"],
    },
}

#: The canonical metrics-snapshot bucket ladder (mirrors
#: repro.obs.metrics.BUCKET_EDGES_US; duplicated so this validator stays
#: dependency-free, with tests/test_obs.py pinning the real one).
_BUCKET_EDGES_US = [2 ** k for k in range(22)]


def _check_metrics_snapshot(record: dict, path: str,
                            errors: list[str]) -> None:
    """Deep checks beyond key presence: the parts of the snapshot schema
    a refactor could silently break without dropping a key."""
    for name, v in record.get("counters", {}).items():
        if not isinstance(v, int) or isinstance(v, bool) or v < 0:
            errors.append(f"{path}: counter {name!r} must be a "
                          f"non-negative int, got {v!r}")
    for name, h in record.get("histograms", {}).items():
        if not isinstance(h, dict):
            errors.append(f"{path}: histogram {name!r} must be an object")
            continue
        if list(h.get("buckets_us", [])) != _BUCKET_EDGES_US:
            errors.append(f"{path}: histogram {name!r} bucket ladder "
                          f"differs from the canonical log2 edges")
        counts = h.get("counts", [])
        if len(counts) != len(_BUCKET_EDGES_US) + 1:
            errors.append(f"{path}: histogram {name!r} counts length "
                          f"{len(counts)} != {len(_BUCKET_EDGES_US) + 1}")
        elif sum(counts) != h.get("count"):
            errors.append(f"{path}: histogram {name!r} bucket counts sum "
                          f"{sum(counts)} != count {h.get('count')}")


_TARGET_KEYS = ["name", "bits", "low_rank", "arch", "checks", "n_sites",
                "sites", "saturation", "state_closed", "findings",
                "proved_overflow_free"]
_SITE_KEYS = ["site", "op", "declared_bits", "lo", "hi", "bits_needed",
              "margin_bits"]


def _check_analysis_report(record: dict, path: str,
                           errors: list[str]) -> None:
    """Deep checks for the repro.analysis report: every qlint target
    carries a full per-site proof table, findings/suppressions are
    well-formed, and the summary counts are consistent."""
    targets = record.get("qlint", {}).get("targets")
    if not isinstance(targets, list):
        errors.append(f"{path}: qlint.targets must be a list")
        return
    n_findings = 0
    for t in targets:
        tname = t.get("name", "?")
        for key in _TARGET_KEYS:
            if key not in t:
                errors.append(f"{path}: target {tname!r} missing {key!r}")
        for i, s in enumerate(t.get("sites", [])):
            for key in _SITE_KEYS:
                if key not in s:
                    errors.append(f"{path}: target {tname!r} sites[{i}] "
                                  f"missing {key!r}")
        n_findings += len(t.get("findings", []))
        if t.get("proved_overflow_free") != (not t.get("findings")):
            errors.append(f"{path}: target {tname!r} "
                          f"proved_overflow_free inconsistent with its "
                          f"findings list")
    det = record.get("detlint", {})
    n_suppressed = 0
    if not det.get("skipped"):
        for key in ("root", "files", "checks", "findings", "suppressions"):
            if key not in det:
                errors.append(f"{path}: detlint missing key {key!r}")
        for f in det.get("findings", []):
            if not all(k in f for k in ("check", "where", "message")):
                errors.append(f"{path}: malformed detlint finding {f!r}")
        for s in det.get("suppressions", []):
            if not all(k in s for k in ("check", "where", "reason")):
                errors.append(f"{path}: malformed suppression {s!r}")
        n_findings += len(det.get("findings", []))
        n_suppressed = len(det.get("suppressions", []))
    summary = record.get("summary", {})
    if summary.get("findings") != n_findings:
        errors.append(f"{path}: summary.findings "
                      f"{summary.get('findings')} != counted {n_findings}")
    if summary.get("suppressed") != n_suppressed:
        errors.append(f"{path}: summary.suppressed "
                      f"{summary.get('suppressed')} != counted "
                      f"{n_suppressed}")
    if summary.get("ok") != (n_findings == 0):
        errors.append(f"{path}: summary.ok inconsistent with findings")


def _walk_numbers(obj, path, errors):
    if isinstance(obj, bool):
        return
    if isinstance(obj, (int, float)):
        if not math.isfinite(obj):
            errors.append(f"{path}: non-finite number {obj!r}")
    elif isinstance(obj, dict):
        for k, v in obj.items():
            _walk_numbers(v, f"{path}.{k}", errors)
    elif isinstance(obj, list):
        for i, v in enumerate(obj):
            _walk_numbers(v, f"{path}[{i}]", errors)


def validate(path: str) -> tuple[str | None, list[str]]:
    """-> (benchmark kind, list of schema errors; empty = valid)."""
    errors: list[str] = []
    try:
        with open(path) as f:
            record = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return None, [f"{path}: unreadable ({e})"]
    kind = record.get("benchmark")
    schema = SCHEMAS.get(kind)
    if schema is None:
        return kind, [f"{path}: unknown benchmark kind {kind!r} "
                      f"(known: {sorted(SCHEMAS)})"]
    for key in schema["top"]:
        if key not in record:
            errors.append(f"{path}: missing top-level key {key!r}")
    if kind == "metrics_snapshot" and not errors:
        _check_metrics_snapshot(record, path, errors)
    if kind == "analysis_report" and not errors:
        _check_analysis_report(record, path, errors)
    for sub in ("size", "capacity", "recovery", "baseline", "traced",
                "budgets", "deadline", "flight_recorder", "kernel_roofline",
                "summary", "overhead", "counter_parity", "drift_demo",
                "crosscheck"):
        if sub not in schema:
            continue
        block = record.get(sub)
        if not isinstance(block, dict):
            errors.append(f"{path}: {sub!r} must be an object")
        else:
            for key in schema[sub]:
                if key not in block:
                    errors.append(f"{path}: {sub} missing key {key!r}")
    rows = record.get("results")
    if "row" in schema:
        if not isinstance(rows, list) or not rows:
            errors.append(f"{path}: 'results' must be a non-empty list")
        else:
            for i, row in enumerate(rows):
                for key in schema["row"]:
                    if key not in row:
                        errors.append(
                            f"{path}: results[{i}] missing key {key!r}")
    _walk_numbers(record, path, errors)
    return kind, errors


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python -m benchmarks.validate_bench BENCH_*.json")
        return 2
    failures = 0
    for path in argv:
        kind, errors = validate(path)
        if errors:
            failures += 1
            for e in errors:
                print(f"FAIL  {e}")
        else:
            print(f"ok    {path} ({kind})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
