"""Render the §Roofline markdown table from results/dryrun.jsonl into
EXPERIMENTS.md (replaces everything after the ROOFLINE_TABLE marker)."""
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "results", "dryrun.jsonl")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")
MARK = "<!-- ROOFLINE_TABLE -->"


def fmt(v, p=3):
    return f"{v:.{p}g}"


def main():
    best = {}
    for line in open(RESULTS):
        r = json.loads(line)
        best[(r["arch"], r["shape"], r["mesh"])] = r
    lines = [
        "",
        "| arch | shape | mesh | t_compute (s) | t_memory (s) | t_collective (s) "
        "| bottleneck | useful_flops | roofline_frac | notes |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for (arch, shape, mesh), r in sorted(
            best.items(), key=lambda kv: (kv[0][0], order[kv[0][1]], kv[0][2])):
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | {mesh} | — | — | — | skipped | — | — "
                         f"| {r['reason']} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {arch} | {shape} | {mesh} | ERROR: "
                         f"{r.get('error','')[:60]} | | | | | | |")
            continue
        rf = r["roofline"]
        notes = []
        if r.get("seq_parallel"):
            notes.append("seq-parallel")
        if r.get("analytic", {}).get("notes"):
            notes.append(r["analytic"]["notes"])
        lines.append(
            f"| {arch} | {shape} | {mesh} | {fmt(rf['t_compute_s'])} "
            f"| {fmt(rf['t_memory_s'])} | {fmt(rf['t_collective_s'])} "
            f"| {rf['bottleneck']} | {rf['useful_flops_fraction']:.3f} "
            f"| **{rf['roofline_fraction']:.3f}** | {'; '.join(notes)} |")
    n_ok = sum(1 for r in best.values() if r["status"] == "ok")
    n_skip = sum(1 for r in best.values() if r["status"] == "skipped")
    lines.append("")
    lines.append(f"({n_ok} compiled cells, {n_skip} assignment-rule skips; "
                 "decode rows are latency-bound serving points — see §3.)")
    src = open(EXP).read()
    head = src.split(MARK)[0]
    open(EXP, "w").write(head + MARK + "\n" + "\n".join(lines) + "\n")
    print(f"rendered {n_ok} ok + {n_skip} skipped rows into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
