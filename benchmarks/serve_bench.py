"""Continuous batching vs the window-boundary baseline: LM tokens/s at
mixed sequence lengths.

    PYTHONPATH=src python -m benchmarks.serve_bench \
        [--arch deepseek-7b] [--slots 8] [--requests 32] [--smoke] \
        [--out BENCH_serve.json]

Both modes run the SAME rewritten engine (serve/engine.py on
serve/scheduler.py); only the scheduler's admission policy differs:

  * ``window``     — ``admit_policy="all_free"``: a new wave of requests is
    admitted only when every slot is free, i.e. each wave runs as long as
    its longest sequence.  This is exactly the old engine's "slot reuse at
    window boundaries" behaviour, kept as a measurable baseline.
  * ``continuous`` — ``admit_policy="any_free"``: a finished sequence's
    KV-cache slot is re-prefilled from the pending queue on the next tick.

With mixed generation lengths the baseline idles short sequences' slots
until the wave's straggler finishes; continuous batching keeps them
packed.  The emitted record carries both modes' tokens/s plus the
scheduler counters (admissions / recycles / spills / occupancy).
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

import repro.configs as C
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig


def mixed_budgets(rng, n, lo, hi, long_lo, long_hi, long_frac=0.25):
    """Mostly-short generation budgets with a heavy tail of stragglers —
    the regime where window-boundary batching wastes the most slot time."""
    budgets = rng.integers(lo, hi + 1, n)
    n_long = max(1, int(round(long_frac * n)))
    long_rows = rng.choice(n, size=n_long, replace=False)
    budgets[long_rows] = rng.integers(long_lo, long_hi + 1, n_long)
    return budgets


def run_mode(policy, cfg, params, prompts, budgets, max_len, slots,
             repeats=3):
    eng = Engine(cfg, params, ServeConfig(max_len=max_len, max_slots=slots,
                                          admit_policy=policy))
    # warm the jit caches (prefill at this prompt geometry + decode tick)
    # so neither mode is billed for compilation; per-round counter deltas
    # keep the warm-up out of the record
    eng.submit(prompts[0], 2)
    eng.run()
    tokens = int(np.sum(budgets))
    best = None
    for _ in range(repeats):           # best-of-N: shrug off load spikes
        st0, sched0 = eng.stats(), eng.stats()["scheduler"]
        t0 = time.perf_counter()
        rids = [eng.submit(p, int(b)) for p, b in zip(prompts, budgets)]
        eng.run()
        wall = time.perf_counter() - t0
        for rid, b in zip(rids, budgets):
            got = eng.result(rid)
            assert got.shape == (b,), (rid, got.shape, b)
        st = eng.stats()
        sched = dict(st["scheduler"])
        for key in ("admissions", "recycles", "spills", "completed",
                    "cancelled", "ticks"):
            sched[key] -= sched0[key]
        row = {
            "mode": "continuous" if policy == "any_free" else "window",
            "admit_policy": policy,
            "requests": len(rids),
            "tokens": tokens,
            "wall_s": round(wall, 4),
            "tokens_per_sec": round(tokens / wall, 2),
            "decode_ticks": st["decode_ticks"] - st0["decode_ticks"],
            "prefills": st["prefills"] - st0["prefills"],
            "scheduler": sched,
        }
        if best is None or row["wall_s"] < best["wall_s"]:
            best = row
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=list(C.ARCHS))
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI schema validation")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()

    full = C.get(args.arch)
    if not full.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    cfg = C.reduced(full, compute_dtype="float32", param_dtype="float32")
    params = T.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    if args.smoke:
        slots, n, max_len = 2, 6, 48
        budgets = mixed_budgets(rng, n, 3, 6, 12, 16)
    else:
        slots, n, max_len = args.slots, args.requests, 160
        budgets = mixed_budgets(rng, n, 8, 24, 96, 128)
    prompts = rng.integers(0, cfg.vocab_size, (n, args.prompt_len))

    results = []
    for policy in ("all_free", "any_free"):
        r = run_mode(policy, cfg, params, prompts, budgets, max_len, slots)
        results.append(r)
        print(f"{r['mode']:10s}: {r['tokens']} tokens in {r['wall_s']:.2f}s "
              f"= {r['tokens_per_sec']:>8.1f} tok/s  "
              f"({r['decode_ticks']} decode ticks, "
              f"{r['scheduler']['recycles']} recycles)", flush=True)

    speedup = results[1]["tokens_per_sec"] / results[0]["tokens_per_sec"]
    record = {
        "benchmark": "serve_continuous_batching",
        "model": f"{args.arch} (reduced, f32)",
        "slots": slots,
        "requests": n,
        "prompt_len": args.prompt_len,
        "budgets": {"min": int(budgets.min()), "max": int(budgets.max()),
                    "mean": round(float(budgets.mean()), 1)},
        "host": {"platform": platform.platform(),
                 "jax": jax.__version__,
                 "device": str(jax.devices()[0])},
        "results": results,
        "speedup_tokens_per_sec": round(speedup, 3),
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"continuous/window speedup: {speedup:.2f}x -> wrote {args.out}")


if __name__ == "__main__":
    main()
