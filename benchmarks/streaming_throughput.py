"""Streaming engine throughput: streams/sec + per-step latency percentiles.

    PYTHONPATH=src python -m benchmarks.streaming_throughput \
        [--out BENCH_streaming.json] [--backends exact,jit] [--windows 2] \
        [--shards N]

``--shards N`` (N > 1) drives the identical protocol through the sharded
``serve/fleet.FleetEngine`` front door — the slot budget splits across N
per-shard slot schedulers ticked by one fused kernel dispatch; see
``benchmarks/fleet_bench.py`` for the dedicated scaling/capacity study.

Drives the multi-stream engine at several concurrency levels with every
slot busy each tick (the steady-state regime: N live 50 Hz sensors), and
emits a JSON perf record so later PRs have a trajectory:

  * ``stream_steps_per_sec`` — total samples advanced per wall second;
  * ``streams_per_sec``      — completed 128-sample windows per second;
  * ``p50_ms`` / ``p99_ms``  — per-tick (one step across all streams)
    latency percentiles;
  * ``realtime_streams_50hz`` — how many live 50 Hz sensors this single
    process sustains in real time (stream_steps_per_sec / 50).

Model weights are random-init + Q15 PTQ (throughput does not depend on
training); the exact backend's bit-identity contract is asserted in
tests/test_streaming.py, not here.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.core import fastgrnn as fg
from repro.core.quantization import quantize_params, QuantConfig
from repro.data import hapt
from repro.obs import MetricsRegistry, Observability
from repro.serve.fleet import FleetConfig, FleetEngine
from repro.serve.streaming import StreamingEngine, StreamingConfig

FULL = os.environ.get("REPRO_FULL", "0") == "1"
CONCURRENCY = (256, 1024, 2048, 4096) if FULL else (256, 1024, 2048)


def _make_engine(qp, n_streams: int, backend: str, shards: int, obs=None):
    """--shards > 1 drives the identical protocol through the sharded
    fleet front door (serve/fleet) instead of one StreamingEngine — the
    slot budget is split across per-shard schedulers."""
    if shards <= 1:
        return StreamingEngine(
            qp, StreamingConfig(max_slots=n_streams, backend=backend),
            obs=obs)
    per_shard = max(1, n_streams // shards)
    return FleetEngine(qp, FleetConfig(
        shards=shards, max_pending_per_shard=0, placement="host",
        stream=StreamingConfig(max_slots=per_shard, backend=backend)),
        obs=obs)


def bench_backend(backend: str, windows: np.ndarray, n_windows: int,
                  qp, concurrency=CONCURRENCY, shards: int = 1,
                  obs=None) -> list[dict]:
    rows = []
    for n_streams in concurrency:
        eng = _make_engine(qp, n_streams, backend, shards, obs=obs)
        n_streams = (n_streams if shards <= 1
                     else shards * max(1, n_streams // shards))
        src = windows[np.arange(n_streams) % len(windows)]
        total = 128 * n_windows
        for i in range(n_streams):
            eng.attach(f"s{i}", total_steps=total)
            eng.feed(f"s{i}", np.tile(src[i], (n_windows, 1)))
        eng.step()                               # warm-up tick (jit compile)
        tick_s = []
        t_start = time.perf_counter()
        done = 1
        while done < total:
            t0 = time.perf_counter()
            eng.step()
            tick_s.append(time.perf_counter() - t0)
            done += 1
        elapsed = time.perf_counter() - t_start
        stats = eng.stats()
        assert stats["completed"] == n_streams, stats
        steps = n_streams * (total - 1)          # steps in the timed region
        tick_ms = np.asarray(tick_s) * 1e3
        rows.append({
            "backend": backend,
            "concurrent_streams": n_streams,
            "ticks": len(tick_s),
            "stream_steps_per_sec": round(steps / elapsed, 1),
            "streams_per_sec": round(n_streams * n_windows / elapsed, 2),
            "p50_ms": round(float(np.percentile(tick_ms, 50)), 4),
            "p99_ms": round(float(np.percentile(tick_ms, 99)), 4),
            "mean_ms": round(float(np.mean(tick_ms)), 4),
            "realtime_streams_50hz": int(steps / elapsed / 50.0),
        })
        print(f"{backend:6s} S={n_streams:5d}: "
              f"{rows[-1]['stream_steps_per_sec']:>12,.0f} steps/s  "
              f"{rows[-1]['streams_per_sec']:>8.1f} windows/s  "
              f"p50 {rows[-1]['p50_ms']:.3f} ms  p99 {rows[-1]['p99_ms']:.3f} ms",
              flush=True)
    return rows


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="BENCH_streaming.json")
    parser.add_argument("--backends", default="exact,jit")
    parser.add_argument("--windows", type=int, default=2,
                        help="128-sample windows per stream")
    parser.add_argument("--concurrency", default=None,
                        help="comma-separated stream counts (CI smoke: 64)")
    parser.add_argument("--shards", type=int, default=1,
                        help="> 1: drive the same protocol through the "
                             "sharded FleetEngine (serve/fleet)")
    parser.add_argument("--metrics-out", default=None,
                        help="also run with the repro.obs metrics registry "
                             "attached and write its snapshot (schema "
                             "'metrics_snapshot') to this path")
    args = parser.parse_args()
    concurrency = (tuple(int(c) for c in args.concurrency.split(","))
                   if args.concurrency else CONCURRENCY)
    # metrics-only bundle: counters/gauges/histograms accumulate across
    # every row; no tracer, so the measured path stays the NullTracer one
    obs = (Observability(metrics=MetricsRegistry())
           if args.metrics_out else None)

    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    qp = quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                         QuantConfig())
    windows = hapt.load("test", n=256).windows

    rows = []
    for backend in args.backends.split(","):
        rows += bench_backend(backend.strip(), windows, args.windows, qp,
                              concurrency, shards=args.shards, obs=obs)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(obs.metrics.dumps() + "\n")
        print(f"wrote {args.metrics_out}")

    record = {
        "benchmark": "streaming_throughput",
        "model": "FastGRNN H=16 r_w=2 r_u=8, Q15 PTQ (566-byte class)",
        "sample_rate_hz": 50.0,
        "window": 128,
        "shards": args.shards,
        "host": {"platform": platform.platform(),
                 "jax": jax.__version__,
                 "device": str(jax.devices()[0])},
        "results": rows,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
