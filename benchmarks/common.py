"""Shared benchmark infrastructure: trained-model cache + timing helper.

Benchmark scale is controlled by REPRO_FULL=1 (paper-scale: full splits,
100 epochs, 5 seeds) vs the default quick mode (3000 train windows, 80
epochs, 2 seeds) so `python -m benchmarks.run` stays CI-sized.  Trained
params are cached under results/bench_cache/.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import fastgrnn as fg, pipeline as pl, compression as comp
from repro.data import hapt

FULL = os.environ.get("REPRO_FULL", "0") == "1"
SEEDS = (0, 1, 2, 3, 4) if FULL else (0, 1)
EPOCHS = 100 if FULL else 80
N_TRAIN = None if FULL else 3000
N_TEST = None if FULL else 1200
CACHE = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                     "results", "bench_cache")


def data():
    tr = hapt.load("train", n=N_TRAIN)
    te = hapt.load("test", n=N_TEST)
    return tr, te


def _cache_path(tag: str, seed: int) -> str:
    os.makedirs(CACHE, exist_ok=True)
    scale = "full" if FULL else "quick"
    return os.path.join(CACHE, f"{tag}_s{seed}_{scale}.npz")


def train_cached(cfg: fg.FastGRNNConfig, tag: str, seed: int,
                 iht: comp.IHTConfig | None = None,
                 epochs: int | None = None):
    """Train (or load) one configuration; returns the param dict."""
    path = _cache_path(tag, seed)
    if os.path.exists(path):
        with np.load(path) as z:
            return {k: z[k] for k in z.files}
    tr, _ = data()
    res = pl.train_fastgrnn(cfg, tr.windows, tr.labels,
                            epochs=epochs or EPOCHS, seed=seed, iht=iht)
    np.savez(path, **{k: np.asarray(v) for k, v in res.params.items()})
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def time_call(fn, *args, reps: int = 5, warmup: int = 1) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def csv_row(name: str, us_per_call: float | str, derived: str) -> str:
    return f"{name},{us_per_call},{derived}"
