"""Assignment Sec. Roofline: render the per-(arch x shape x mesh) roofline
table from results/dryrun.jsonl (produced by repro.launch.dryrun)."""
from __future__ import annotations

import json
import os

from . import common

RESULTS = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "results", "dryrun.jsonl")


def roofline_table():
    if not os.path.exists(RESULTS):
        return [common.csv_row("roofline_table", "",
                               "missing results/dryrun.jsonl — run "
                               "python -m repro.launch.dryrun --all --out results/dryrun.jsonl")]
    best = {}
    for line in open(RESULTS):
        r = json.loads(line)
        best[(r["arch"], r["shape"], r["mesh"])] = r   # keep latest
    rows = []
    for (arch, shape, mesh), r in sorted(best.items()):
        if r["status"] == "skipped":
            rows.append(common.csv_row(f"roofline_{arch}_{shape}_{mesh}", "",
                                       f"skipped:{r['reason']}"))
            continue
        if r["status"] != "ok":
            rows.append(common.csv_row(f"roofline_{arch}_{shape}_{mesh}", "",
                                       f"ERROR:{r.get('error','')[:80]}"))
            continue
        rf = r["roofline"]
        rows.append(common.csv_row(
            f"roofline_{arch}_{shape}_{mesh}", "",
            f"tC={rf['t_compute_s']:.3g}s;tM={rf['t_memory_s']:.3g}s;"
            f"tX={rf['t_collective_s']:.3g}s;bottleneck={rf['bottleneck']};"
            f"useful_flops={rf['useful_flops_fraction']:.3f};"
            f"roofline_frac={rf['roofline_fraction']:.3f}"))
    return rows
