"""Crash-failover benchmark: how fast a dead 16k-stream shard comes back.

    PYTHONPATH=src python -m benchmarks.failover_bench \
        [--out BENCH_failover.json] [--backend jit] \
        [--shards 2] [--slots-per-shard 16384] [--snapshot-every 16] \
        [--samples 256] [--reps 5] [--smoke]

Measures the two failover costs on a fully-resident fleet:

* **snapshot_ms** — one full checkpoint pass (``FleetEngine.snapshot_now``):
  wire-encode every live stream's :class:`StreamState` into the snapshot
  store.  This is the steady-state tax paid every ``snapshot_every`` ticks.
* **recovery_ms** — ``FleetEngine.crash_shard(0)``: drop the shard's
  engine, build a replacement, decode every lost stream's snapshot and
  queue its journal replay.  This is the unavailability window of the
  crashed shard's streams (the paper-level claim: recovery is a bounded
  engineering cost, correctness is free — bit-exactness is gated in
  tests/test_failover.py, not here).

The default configuration kills a shard holding 16,384 resident streams
(the capacity-unit shard width of ``fleet_bench.py``) and reports
median/p99 over ``--reps`` crash/rebuild cycles.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from repro.core import fastgrnn as fg
from repro.core.quantization import quantize_params, QuantConfig
from repro.data import hapt
from repro.serve.fleet import FleetConfig, FleetEngine
from repro.serve.streaming import StreamingConfig


def _build(qp, args, obs=None) -> FleetEngine:
    stream = StreamingConfig(
        max_slots=args.slots_per_shard, backend=args.backend,
        batch_events=True, ring_capacity=args.samples,
        max_ring_capacity=args.samples)
    return FleetEngine(qp, FleetConfig(
        shards=args.shards, stream=stream, max_pending_per_shard=0,
        placement="host", snapshot_every=args.snapshot_every),
        obs=obs)


def _fill(fleet: FleetEngine, src: np.ndarray, n_streams: int,
          samples: int) -> None:
    reps = -(-samples // (len(src[0])))          # ceil windows per stream
    for i in range(n_streams):
        fleet.attach(f"s{i}", total_steps=None)
        fleet.feed(f"s{i}", np.tile(src[i % len(src)], (reps, 1))[:samples])


def _one_rep(qp, src, args, rep: int, obs=None) -> dict:
    fleet = _build(qp, args, obs=obs)
    n_streams = args.shards * args.slots_per_shard
    _fill(fleet, src, n_streams, args.samples)
    for _ in range(args.ticks_before):           # reach steady state (the
        fleet.step()                             # cadence checkpoints too)
    t0 = time.perf_counter()
    stored = fleet.snapshot_now()
    snapshot_ms = (time.perf_counter() - t0) * 1e3
    for _ in range(args.ticks_between):          # dirty the journal a bit
        fleet.step()
    t0 = time.perf_counter()
    report = fleet.crash_shard(0)
    recovery_ms = (time.perf_counter() - t0) * 1e3
    assert stored == n_streams, (stored, n_streams)
    assert report["streams_recovered"] == args.slots_per_shard, report
    return {
        "rep": rep,
        "streams_recovered": report["streams_recovered"],
        "replayed_samples": report["replayed_samples"],
        "wire_bytes": report["wire_bytes"],
        "snapshot_ms": round(snapshot_ms, 3),
        "recovery_ms": round(recovery_ms, 3),
        "recovery_us_per_stream": round(
            recovery_ms * 1e3 / report["streams_recovered"], 3),
    }


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="BENCH_failover.json")
    parser.add_argument("--backend", default="jit",
                        choices=("exact", "jit", "pallas"))
    parser.add_argument("--shards", type=int, default=2)
    parser.add_argument("--slots-per-shard", type=int, default=16384,
                        help="streams lost when shard 0 dies (the "
                             "fleet_bench capacity-unit width)")
    parser.add_argument("--snapshot-every", type=int, default=16)
    parser.add_argument("--samples", type=int, default=256,
                        help="samples buffered per stream")
    parser.add_argument("--ticks-before", type=int, default=20)
    parser.add_argument("--ticks-between", type=int, default=8,
                        help="ticks between the timed checkpoint and the "
                             "crash (journal depth at recovery)")
    parser.add_argument("--reps", type=int, default=5)
    parser.add_argument("--smoke", action="store_true",
                        help="CI configuration: tiny fleet, 2 reps")
    parser.add_argument("--metrics-out", default=None, metavar="PATH",
                        help="also dump a metrics_snapshot JSON: the "
                             "fleet's registry (tick/crash series plus "
                             "numeric-health counters) across all reps")
    args = parser.parse_args()
    if args.smoke:
        args.slots_per_shard, args.samples = 256, 64
        args.ticks_before, args.reps = 10, 2

    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    qp = quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                         QuantConfig())
    src = hapt.load("test", n=256).windows

    obs = None
    if args.metrics_out:
        from repro.obs import MetricsRegistry, Observability
        from repro.obs.numerics import NumericsMonitor
        obs = Observability(metrics=MetricsRegistry(),
                            numerics=NumericsMonitor())

    rows = []
    for rep in range(args.reps):
        row = _one_rep(qp, src, args, rep, obs=obs)
        rows.append(row)
        print(f"rep {rep}: snapshot {row['snapshot_ms']:8.1f} ms   "
              f"crash+recover {row['recovery_ms']:8.1f} ms   "
              f"({row['streams_recovered']:,} streams, "
              f"{row['replayed_samples']:,} samples replayed)", flush=True)

    rec = np.array([r["recovery_ms"] for r in rows])
    snap = np.array([r["snapshot_ms"] for r in rows])
    recovery = {
        "streams": args.slots_per_shard,
        "recovery_ms_p50": round(float(np.percentile(rec, 50)), 3),
        "recovery_ms_p99": round(float(np.percentile(rec, 99)), 3),
        "snapshot_ms_p50": round(float(np.percentile(snap, 50)), 3),
        "recovery_us_per_stream_p50": round(float(np.percentile(
            [r["recovery_us_per_stream"] for r in rows], 50)), 3),
        "wire_mb_per_shard": round(
            rows[0]["wire_bytes"] / 1e6, 3),
    }
    print(f"recovery of a {args.slots_per_shard:,}-stream shard: "
          f"p50 {recovery['recovery_ms_p50']:.1f} ms, "
          f"p99 {recovery['recovery_ms_p99']:.1f} ms "
          f"({recovery['recovery_us_per_stream_p50']:.1f} us/stream)",
          flush=True)

    record = {
        "benchmark": "fleet_failover",
        "model": "FastGRNN H=16 r_w=2 r_u=8, Q15 PTQ (566-byte class)",
        "backend": args.backend,
        "shards": args.shards,
        "slots_per_shard": args.slots_per_shard,
        "snapshot_every": args.snapshot_every,
        "samples_per_stream": args.samples,
        "host": {"platform": platform.platform(),
                 "cpus": __import__("os").cpu_count(),
                 "jax": jax.__version__,
                 "device": str(jax.devices()[0])},
        "results": rows,
        "recovery": recovery,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    if obs is not None:
        with open(args.metrics_out, "w") as f:
            f.write(obs.metrics.dumps() + "\n")
        print(f"wrote {args.metrics_out}")


if __name__ == "__main__":
    main()
