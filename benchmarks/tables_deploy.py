"""Paper Tables VI-IX + Sec. V-G/VI-A: deployment behaviour — bit
equivalence, streaming latency (MCU cycle model), energy, warm-up, LUT
speedup — plus TPU-kernel timings (CPU interpret-mode, labeled as such).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import fastgrnn as fg, pipeline as pl, compression as comp
from repro.core import energy as en, mcu, warmup
from repro.core.lut import lut_sigmoid, lut_tanh
from repro.kernels.fastgrnn_cell.ops import fastgrnn_window_kernel

from . import common

CFG = fg.FastGRNNConfig(rank_w=2, rank_u=8)


def _deployed(seed: int = 0):
    iht = comp.IHTConfig(target_sparsity=0.5, ramp_epochs=common.EPOCHS // 2)
    sp = common.train_cached(CFG, "t2_sparse", seed, iht=iht)
    tr, te = common.data()
    return pl.deploy(sp, tr.windows[:5]), sp, tr, te


def table6_bitequiv():
    """Table VI + Sec. V-F: three-path agreement and the h_0 trajectory
    samples at t = 25, 50, 75, 100, 125, 128."""
    rt, sp, tr, te = _deployed()
    n = 400 if not common.FULL else len(te.windows)
    wins = te.windows[:n]
    p_int = rt.predict_batch(wins)
    p_lut = pl.predict_fp32(sp, wins,
                            sigma=lambda x: lut_sigmoid(x, "nearest"),
                            tanh=lambda x: lut_tanh(x, "nearest"))
    deq = rt.qp.dequantize()
    h, _ = fastgrnn_window_kernel(deq, jnp.asarray(np.transpose(wins, (1, 0, 2))))
    logits = np.asarray(h) @ np.asarray(deq["head_w"]) + np.asarray(deq["head_b"])
    p_kern = np.argmax(logits, -1)
    rows = [
        common.csv_row("table6_agree_int_vs_kernel", "",
                       f"agreement={pl.agreement(p_int, p_kern):.4f};n={n}"),
        common.csv_row("table6_agree_fp32lut_vs_int", "",
                       f"agreement={pl.agreement(p_lut, p_int):.4f};n={n}"),
    ]
    _, traj = rt.run_window(te.windows[0], return_trajectory=True)
    samples = ";".join(f"t{t}={traj[t-1][0]:+.3f}" for t in (25, 50, 75, 100, 125, 128))
    rows.append(common.csv_row("table6_h0_trajectory", "", samples))
    return rows


def table7_streaming():
    """Table VII: 50 Hz paced streaming latency (MCU cycle MODEL, fitted
    to the paper's measured endpoints — core/mcu.py docstring)."""
    rows = []
    for plat in (mcu.ARDUINO, mcu.MSP430):
        t = mcu.step_latency_s(CFG, plat, lut=True)
        rows.append(common.csv_row(
            f"table7_{plat.name.split()[0].lower()}", f"{t*1e6:.0f}",
            f"avg_ms={t*1e3:.2f};budget_use={mcu.budget_use(CFG, plat):.2f};"
            f"over_budget={'0/128' if t < 0.02 else '128/128'}"))
    return rows


def table89_energy():
    """Tables VIII-IX: measured constants -> derived energy figures."""
    return [
        common.csv_row("table8_p_active_mw", "", f"{en.MSP430_LUT.p_active_mw:.1f}"),
        common.csv_row("table8_p_idle_mw", "", f"<{en.MSP430_LUT.p_idle_mw:.3f}"),
        common.csv_row("table9_e_inference_uj_lut", "", f"{en.LUT_BUILD.e_inference_uj:.0f}"),
        common.csv_row("table9_e_window_mj_lut", "", f"{en.LUT_BUILD.e_window_mj:.1f}"),
        common.csv_row("table9_e_inference_uj_nolut", "", f"{en.NO_LUT_BUILD.e_inference_uj:.0f}"),
        common.csv_row("table9_battery_h_stream", "", f"{en.LUT_BUILD.battery_hours(False):.0f}"),
        common.csv_row("table9_battery_h_cont", "", f"{en.LUT_BUILD.battery_hours(True):.0f}"),
        common.csv_row("table9_energy_reduction", "", f"{en.window_energy_reduction()*100:.1f}%"),
    ]


def warmup_latency():
    """Sec. VI-A / Fig. 8: stabilization distribution over 100 windows."""
    rt, sp, tr, te = _deployed()
    n = 100
    preds = []
    for w in te.windows[:n]:
        _, traj = rt.run_window(w, return_trajectory=True)
        step_logits = traj @ np.asarray(rt._w["head_w"]) + np.asarray(rt._head_b)
        preds.append(np.argmax(step_logits, -1))
    st = warmup.characterize(np.stack(preds))
    rows = [common.csv_row(
        "warmup_fastgrnn", "",
        f"median={st.median_samples:.0f}({st.median_seconds:.2f}s);"
        f"iqr={st.iqr_lo:.0f}-{st.iqr_hi:.0f};worst={st.worst_case}"
        f"({st.worst_seconds:.2f}s);n={st.n_windows}")]
    return rows


def lut_speedup():
    """Sec. V-G: the 30.5x MSP430 LUT speedup (cycle model) + the TPU-side
    framing (determinism, not speed) with interpret-mode kernel timing."""
    rows = [
        common.csv_row("lut_speedup_msp430_model", "",
                       f"{mcu.lut_speedup(CFG, mcu.MSP430):.1f}x"),
        common.csv_row("lut_speedup_arduino_model", "",
                       f"{mcu.lut_speedup(CFG, mcu.ARDUINO):.2f}x"),
        common.csv_row("lut_speedup_energy_model", "",
                       f"{en.lut_speedup():.1f}x;window_54s_to_1.8s"),
    ]
    # TPU-kernel path (interpret on CPU — NOT a TPU timing; recorded for
    # regression tracking only)
    from repro.kernels.lut_act.ops import lut_tanh as k_tanh
    x = jnp.asarray(np.random.default_rng(0).normal(size=(128, 128)), jnp.float32)
    us = common.time_call(lambda v: k_tanh(v).block_until_ready(), x, reps=3)
    rows.append(common.csv_row("lut_kernel_interpret_cpu", f"{us:.0f}",
                               "interpret-mode;regression-tracking-only"))
    return rows
