"""Numeric-health monitor bench: overhead budget, counter parity, drift demo.

    PYTHONPATH=src python -m benchmarks.numerics_bench \
        [--out BENCH_numerics.json] [--windows 128] [--streams 64] [--reps 3]

Pins the four claims the numeric-health observability layer ships with:

  * **overhead** — attaching a live
    :class:`repro.obs.numerics.NumericsMonitor` to the exact-backend
    streaming engine costs <= 10% over the monitor-less ``Observability``
    bundle it rides on (the exact path tallies from intermediates the
    kernel already materializes; the bundle itself is budgeted by
    ``benchmarks/obs_bench.py``), and that null bundle sits at the noise
    floor vs the fully unobserved baseline;
  * **counter_parity** — the ``-DFG_NUMERIC_COUNTERS`` C build reports
    per-site saturation counts exactly equal to the monitored qvm's on
    the same quantized windows, including a x8 input-amplified stress
    segment that must witness ``h_next`` saturation on both sides
    (skipped when no host cc is available);
  * **drift_demo** — injecting input gain 1/2/4/8 produces a
    monotonically non-decreasing calibration-drift score (the score
    moves when the deployment's data distribution does);
  * **crosscheck** — the unmodified-gain runtime witnesses pass the
    static reachability cross-check (:mod:`repro.analysis.crosscheck`).

Timing numbers are wall-clock (host-dependent); every boolean gate and
counter in the record is deterministic.
"""
from __future__ import annotations

import argparse
import json
import platform as _platform
import tempfile
import time

import numpy as np

from repro.data import hapt
from repro.deploy import emit_c
from repro.deploy.goldens import build_reference_artifact
from repro.deploy.image import build_image
from repro.deploy.qvm import QVM
from repro.obs import MetricsRegistry, Observability
from repro.obs.numerics import NumericsMonitor, site_order

#: Input gain that drives the reference model's ``h_next`` site into
#: saturation (the stress witness both engines must agree on).
STRESS_GAIN = 8

#: Acceptance budget: the monitor's marginal exact-backend throughput
#: loss over the monitor-less obs bundle.
MONITOR_BUDGET_PCT = 10.0
#: Noise floor allowance for the monitor-less bundle (this class of
#: 2-core container shows ~5-9% session rep noise — obs_bench records
#: the same as ``measured_noise_pct``; so does this record).
NULL_BUDGET_PCT = 5.0


#: Windows fed back-to-back per stream in the overhead drain — long
#: enough (~1024 ticks) that host scheduling noise stops dominating the
#: sub-100ms single-window measurement.
DRAIN_WINDOWS = 8


def _one_drain_s(art, windows: np.ndarray, make_obs) -> float:
    """Wall time of one full attach+drain pass of the exact engine."""
    from repro.serve.streaming import StreamingConfig, StreamingEngine
    eng = StreamingEngine.from_artifact(
        art, StreamingConfig(max_slots=len(windows), backend="exact"),
        obs=make_obs())
    for i, w in enumerate(windows):
        samples = np.tile(w, (DRAIN_WINDOWS, 1))
        eng.attach(f"w{i}", samples, total_steps=len(samples))
    t0 = time.perf_counter()
    eng.drain()
    return time.perf_counter() - t0


def bench_overhead(art, windows: np.ndarray, reps: int) -> tuple[dict, dict]:
    """Interleaved best-of-``reps`` so thermal / cache drift lands on
    every configuration equally (sequential per-config timing on a
    sub-100ms drain is dominated by host noise)."""
    configs = {
        "baseline": lambda: None,
        "null": lambda: Observability(metrics=MetricsRegistry()),
        "monitored": lambda: Observability(metrics=MetricsRegistry(),
                                           numerics=NumericsMonitor()),
    }
    times = {name: [] for name in configs}
    _one_drain_s(art, windows, configs["baseline"])      # shared warm-up
    for _ in range(reps):
        for name, make_obs in configs.items():
            times[name].append(_one_drain_s(art, windows, make_obs))
    steps = windows.shape[0] * windows.shape[1] * DRAIN_WINDOWS
    base, null, mon = (steps / min(times[k]) for k in
                       ("baseline", "null", "monitored"))
    # Overheads are the MEDIAN over PAIRED per-rep ratios: the three
    # configs inside one rep run back to back and share the host's
    # thermal/scheduling state, so a within-rep ratio is far stabler
    # than a ratio of best-of times taken from different reps, and the
    # median is robust to the occasional rep where noise landed on one
    # side of the pair.
    def _med(xs):
        xs = sorted(xs)
        n = len(xs)
        return xs[n // 2] if n % 2 else (xs[n // 2 - 1] + xs[n // 2]) / 2
    over_mon = _med([100.0 * (tm - tb) / tb for tb, tm in
                     zip(times["baseline"], times["monitored"])])
    over_null = _med([100.0 * (tn - tb) / tb for tb, tn in
                      zip(times["baseline"], times["null"])])
    # the budget gates the MONITOR's marginal cost over the monitor-less
    # obs bundle: the tracer/metrics bundle itself is budgeted separately
    # by benchmarks/obs_bench.py, and a NumericsMonitor only ever runs on
    # top of one
    marginal = _med([100.0 * (tm - tn) / tn for tn, tm in
                     zip(times["null"], times["monitored"])])
    # session rep noise: spread of the *unmonitored* baseline drain
    # across reps — the floor below which overhead deltas are not
    # distinguishable on this host (obs_bench records the same)
    noise = 100.0 * (max(times["baseline"]) - min(times["baseline"])) \
        / min(times["baseline"])
    overhead = {
        "baseline_steps_per_sec": round(base, 1),
        "null_steps_per_sec": round(null, 1),
        "monitored_steps_per_sec": round(mon, 1),
        "null_overhead_pct": round(over_null, 2),
        "monitored_overhead_pct": round(over_mon, 2),
        "monitor_marginal_pct": round(marginal, 2),
        "measured_noise_pct": round(noise, 2),
    }
    budgets = {
        "monitored_budget_pct": MONITOR_BUDGET_PCT,
        "monitored_within_budget": bool(marginal <= MONITOR_BUDGET_PCT),
        "null_budget_pct": NULL_BUDGET_PCT,
        "null_within_noise": bool(over_null <= NULL_BUDGET_PCT),
    }
    return overhead, budgets


def _qvm_counts(img, xq: np.ndarray) -> tuple[np.ndarray, np.ndarray, dict]:
    mon = NumericsMonitor()
    vm = QVM(img, monitor=mon)
    preds = np.argmax(vm.run_windows(xq), axis=1).astype(np.int32)
    snap = mon.snapshot()
    order = site_order(bool(img.low_rank))
    return preds, np.array([snap["sites"][s] for s in order], np.uint64), snap


def bench_counter_parity(img, windows: np.ndarray) -> tuple[dict, dict]:
    """qvm vs counter-instrumented C, golden + stress segments.  Returns
    (parity block, gain-1 qvm snapshot for the crosscheck block)."""
    vm = QVM(img)
    xq = vm.quantize_input(windows)
    xq_stress = vm.quantize_input(
        np.asarray(windows, np.float32) * STRESS_GAIN)
    preds_q, counts_q, snap = _qvm_counts(img, xq)
    _, counts_qs, _ = _qvm_counts(img, xq_stress)
    block = {
        "windows": int(len(windows)),
        "stress_gain": STRESS_GAIN,
        "available": False,
        "counters_equal": None,
        "preds_equal": None,
        "stress_counters_equal": None,
        "stress_h_next": int(counts_qs[site_order(
            bool(img.low_rank)).index("h_next")]),
    }
    if not emit_c.find_cc():
        return block, snap
    with tempfile.TemporaryDirectory() as td:
        binary = emit_c.compile_host(img, td, engine="int",
                                     numeric_counters=True)
        cm = emit_c.CHostModel(binary, img.H, img.C, engine="int")
        preds_c, counts_c = cm.counters(xq)
        _, counts_cs = cm.counters(xq_stress)
    block.update(
        available=True,
        counters_equal=bool(np.array_equal(counts_c, counts_q)),
        preds_equal=bool(np.array_equal(preds_c, preds_q)),
        stress_counters_equal=bool(np.array_equal(counts_cs, counts_qs)),
    )
    return block, snap


def bench_drift(img, windows: np.ndarray) -> dict:
    """Calibration-drift injection: gain sweep -> drift score sweep."""
    scales, scores = (1, 2, 4, 8), []
    for gain in scales:
        mon = NumericsMonitor()
        vm = QVM(img, monitor=mon)
        vm.run_windows(vm.quantize_input(
            np.asarray(windows, np.float32) * gain))
        scores.append(round(mon.drift(), 6))
    return {
        "scales": list(scales),
        "drift_scores": scores,
        "monotone": bool(all(a <= b for a, b in zip(scores, scores[1:]))),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_numerics.json")
    ap.add_argument("--windows", type=int, default=128)
    ap.add_argument("--streams", type=int, default=64,
                    help="streams in the engine-overhead drain")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()

    art = build_reference_artifact(seed=0)
    img = build_image(art)
    test = hapt.load("test", n=max(args.windows, args.streams)).windows

    print("overhead bench ...", flush=True)
    overhead, budgets = bench_overhead(art, test[:args.streams], args.reps)
    print("counter parity ...", flush=True)
    parity, snap = bench_counter_parity(img, test[:args.windows])
    print("drift demo ...", flush=True)
    drift = bench_drift(img, test[:args.windows])
    print("crosscheck ...", flush=True)
    from repro.analysis import crosscheck
    from repro.analysis.qlint import analyze_image
    verdict = crosscheck(analyze_image(img, name="bench"), snap)

    record = {
        "benchmark": "numerics_health",
        "model": "random-init reference export (seed 0)",
        "backend": "exact",
        "host": {"platform": _platform.platform(),
                 "cc": emit_c.find_cc()},
        "config": {"windows": args.windows, "streams": args.streams,
                   "reps": args.reps, "stress_gain": STRESS_GAIN},
        "overhead": overhead,
        "budgets": budgets,
        "counter_parity": parity,
        "drift_demo": drift,
        "crosscheck": verdict,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    print(f"  monitor marginal: {overhead['monitor_marginal_pct']:.1f}% "
          f"(budget {budgets['monitored_budget_pct']:.0f}%); "
          f"vs bare baseline: monitored "
          f"{overhead['monitored_overhead_pct']:.1f}%, "
          f"null {overhead['null_overhead_pct']:.1f}%")
    print(f"  counter parity: {parity}")
    print(f"  drift sweep: {drift['drift_scores']} "
          f"(monotone={drift['monotone']})")
    print(f"  crosscheck ok={verdict['ok']}")


if __name__ == "__main__":
    main()
