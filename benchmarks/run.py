# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
# Quick mode by default (subset data, 2 seeds, cached training);
# REPRO_FULL=1 reproduces the paper-scale protocol (full splits, 100
# epochs, 5 seeds).  Roofline rows read results/dryrun.jsonl.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import tables_accuracy as acc
    from . import tables_deploy as dep
    from . import roofline_table as roof
    from . import beyond_paper as bp

    benches = [
        acc.table1_hidden_size,
        acc.table2_lsq_pipeline,
        acc.table3_per_seed,
        acc.table4_param_footprint,
        acc.table5_quant_modes,
        acc.fig6_per_class,
        dep.table6_bitequiv,
        dep.table7_streaming,
        dep.table89_energy,
        dep.warmup_latency,
        dep.lut_speedup,
        bp.dual_rank_decomposition,       # paper Sec. VI-E direction 1
        bp.warmup_lstm_gru,               # paper Sec. VI-A follow-up
        roof.roofline_table,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for b in benches:
        try:
            for row in b():
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{b.__name__},ERROR,{traceback.format_exc(limit=1)!r}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
