"""Fleet sharding benchmark: aggregate throughput vs shard count, and the
100k-concurrent-stream capacity point.

    PYTHONPATH=src python -m benchmarks.fleet_bench \
        [--out BENCH_fleet.json] [--backend jit] [--slots-per-shard 1024] \
        [--shards 1,2,4,8] [--capacity-shards 8] \
        [--capacity-slots 16384] [--smoke]

Two measurements, one record:

* **Scaling** — shard count sweeps (default 1 -> 8) at a fixed per-shard
  slot width (the capacity unit): every shard is fully resident and every
  slot advances every tick, so aggregate ``stream_steps_per_sec`` is the
  weak-scaling curve.  With fused ticks (one batched kernel dispatch per
  tick regardless of shard count) the per-dispatch fixed cost amortizes
  across shards, which is where the near-linear scaling comes from on
  CPU; per-shard bookkeeping is the part that stays serial.
* **Capacity** — one big fleet (default 8 x 16384 = 131,072 resident
  streams) stepped in steady state; reports aggregate steps/s and
  ``realtime_streams_50hz`` (how many live 50 Hz sensors this one process
  sustains in real time — the paper's per-device workload, multiplied).

Model weights are random-init + Q15 PTQ (throughput does not depend on
training); the fleet's bit-identity contract vs the single engine is
asserted in tests/test_fleet.py, not here.
"""
from __future__ import annotations

import argparse
import json
import platform
import time

import jax
import numpy as np

from repro.core import fastgrnn as fg
from repro.core.quantization import quantize_params, QuantConfig
from repro.data import hapt
from repro.kernels.fastgrnn_cell.ops import Q15StreamStep
from repro.obs import MetricsRegistry, Observability, TRANSFER_KEYS
from repro.serve.fleet import FleetConfig, FleetEngine
from repro.serve.streaming import StreamingConfig


def _build_fleet(qp, shards: int, slots: int, backend: str,
                 windows_per_stream: int, placement: str,
                 obs=None) -> FleetEngine:
    ring = 128 * windows_per_stream
    stream = StreamingConfig(max_slots=slots, backend=backend,
                             batch_events=True,     # columnar emission —
                             # a lockstep window boundary emits the whole
                             # fleet at once; per-object events would cost
                             # more than the tick's model math
                             ring_capacity=ring, max_ring_capacity=ring)
    # max_pending_per_shard=0: a full home shard overflows to the least-
    # loaded shard instead of queueing, so a fleet filled to exactly its
    # capacity is 100% resident — the steady-state regime (every slot
    # advances every tick) the throughput numbers are defined over.
    return FleetEngine(qp, FleetConfig(shards=shards, stream=stream,
                                       max_pending_per_shard=0,
                                       placement=placement), obs=obs)


def _fill(fleet: FleetEngine, src: np.ndarray, n_streams: int,
          windows_per_stream: int) -> None:
    total = 128 * windows_per_stream
    for i in range(n_streams):
        fleet.attach(f"s{i}", total_steps=total)
        fleet.feed(f"s{i}", np.tile(src[i % len(src)],
                                    (windows_per_stream, 1)))


def _run(fleet: FleetEngine, n_streams: int,
         windows_per_stream: int) -> dict:
    total = 128 * windows_per_stream
    fleet.step()                                 # warm-up tick (jit compile)
    # steady-window transfer accounting: the ticks right after warm-up
    # are emission-free (the first window boundary is tick 128), so the
    # h-state byte deltas over this window are the device-residency
    # gate — zero on the resident jit/pallas paths, a full h round
    # trip per tick on the host-staged ones
    steady = min(16, total - 2)
    tr0 = fleet.stats()["transfers"]
    tick_s = []
    t_start = time.perf_counter()
    done = 1
    tr1 = tr0
    while done < total:
        t0 = time.perf_counter()
        fleet.step()
        tick_s.append(time.perf_counter() - t0)
        done += 1
        if done == 1 + steady:
            tr1 = fleet.stats()["transfers"]
    elapsed = time.perf_counter() - t_start
    stats = fleet.stats()
    assert stats["completed"] == n_streams, stats
    steps = n_streams * (total - 1)              # steps in the timed region
    tick_ms = np.asarray(tick_s) * 1e3
    transfers = {k: int(tr1[k] - tr0[k]) for k in TRANSFER_KEYS}
    return {
        "concurrent_streams": n_streams,
        "ticks": len(tick_s),
        "stream_steps_per_sec": round(steps / elapsed, 1),
        "p50_ms": round(float(np.percentile(tick_ms, 50)), 4),
        "p99_ms": round(float(np.percentile(tick_ms, 99)), 4),
        "realtime_streams_50hz": int(steps / elapsed / 50.0),
        "steady_ticks_measured": int(steady),
        "transfers": transfers,
        "zero_copy_h": transfers["h_h2d_bytes"] == 0
        and transfers["h_d2h_bytes"] == 0,
        "scheduler": {k: stats["scheduler"][k] for k in
                      ("admissions", "recycles", "spills", "peak_active")},
    }


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="BENCH_fleet.json")
    parser.add_argument("--backend", default="jit",
                        choices=("exact", "jit", "pallas"))
    parser.add_argument("--placement", default="host,devices",
                        help="comma-separated shard-placement sweep: 'host' "
                             "fuses all shards into one dispatch (the fast "
                             "small-core CPU configuration), 'devices' "
                             "round-robins shards over jax devices and "
                             "issues every group's dispatch before waiting "
                             "on any (skipped when fewer than 2 devices "
                             "exist or the backend is exact)")
    parser.add_argument("--slots-per-shard", type=int, default=1024)
    parser.add_argument("--shards", default="1,2,4,8",
                        help="comma-separated shard counts for the scaling "
                             "sweep")
    parser.add_argument("--capacity-shards", type=int, default=8)
    parser.add_argument("--capacity-slots", type=int, default=16384,
                        help="slots per shard for the capacity point "
                             "(8 x 16384 = 131,072 resident streams)")
    parser.add_argument("--windows", type=int, default=3,
                        help="128-sample windows per stream")
    parser.add_argument("--reps", type=int, default=3,
                        help="repetitions per scaling row (median-of)")
    parser.add_argument("--metrics-out", default=None,
                        help="attach the repro.obs metrics registry and "
                             "write its snapshot (schema "
                             "'metrics_snapshot') to this path")
    parser.add_argument("--smoke", action="store_true",
                        help="CI configuration: tiny fleet, 1 window")
    args = parser.parse_args()
    if args.smoke:
        args.shards, args.slots_per_shard = "1,2", 256
        args.capacity_shards, args.capacity_slots = 4, 256
        args.windows, args.reps = 1, 1
    shard_counts = [int(s) for s in args.shards.split(",")]
    placements = [p.strip() for p in args.placement.split(",") if p.strip()]
    resolved = []
    for p in placements:
        if p == "devices" and (args.backend == "exact"
                               or len(jax.devices()) < 2):
            print(f"skipping placement='devices' (backend={args.backend}, "
                  f"{len(jax.devices())} jax device(s)); run with "
                  f"XLA_FLAGS=--xla_force_host_platform_device_count=N to "
                  f"fake a multi-device CPU topology", flush=True)
            continue
        resolved.append(p)
    if not resolved:
        resolved = ["host"]
    # metrics-only bundle (no tracer): the timed path stays NullTracer
    obs = (Observability(metrics=MetricsRegistry())
           if args.metrics_out else None)

    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    qp = quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                         QuantConfig())
    src = hapt.load("test", n=256).windows

    rows = []
    for placement in resolved:
        base = None
        for n in shard_counts:
            n_streams = n * args.slots_per_shard
            reps = []
            for _ in range(max(1, args.reps)):   # median-of-N: small boxes
                fleet = _build_fleet(qp, n, args.slots_per_shard,
                                     args.backend, args.windows, placement,
                                     obs=obs)
                _fill(fleet, src, n_streams, args.windows)
                reps.append(_run(fleet, n_streams, args.windows))
            reps.sort(key=lambda r: r["stream_steps_per_sec"])
            row = {"shards": n, "placement": placement,
                   **reps[len(reps) // 2]}        # jitter badly
            rows.append(row)
            if base is None:
                base = row["stream_steps_per_sec"]
            row["scaling_x"] = round(row["stream_steps_per_sec"] / base, 2)
            row["scaling_efficiency"] = round(
                row["scaling_x"] / (n / shard_counts[0]), 3)
            print(f"{placement:7s} {n:2d} shards x {args.slots_per_shard}: "
                  f"{row['stream_steps_per_sec']:>12,.0f} steps/s  "
                  f"x{row['scaling_x']:.2f} vs 1 shard  "
                  f"eff {row['scaling_efficiency']:.3f}  "
                  f"p50 {row['p50_ms']:.3f} ms  "
                  f"zero_copy_h={row['zero_copy_h']}", flush=True)

    cap_placement = resolved[0]
    cap_streams = args.capacity_shards * args.capacity_slots
    cap_runs = []
    for rep in range(max(1, args.reps)):   # median-of-N, same as the rows
        cap_fleet = _build_fleet(qp, args.capacity_shards,
                                 args.capacity_slots, args.backend,
                                 args.windows, cap_placement, obs=obs)
        print(f"capacity rep {rep + 1}: filling {cap_streams:,} streams "
              f"...", flush=True)
        _fill(cap_fleet, src, cap_streams, args.windows)
        cap_runs.append(_run(cap_fleet, cap_streams, args.windows))
    cap_runs.sort(key=lambda r: r["stream_steps_per_sec"])
    capacity = {"shards": args.capacity_shards,
                "slots_per_shard": args.capacity_slots,
                "placement": cap_placement,
                **cap_runs[len(cap_runs) // 2]}
    capacity["sustained_realtime_50hz"] = bool(
        capacity["realtime_streams_50hz"] >= cap_streams)
    print(f"capacity: {cap_streams:,} concurrent streams, "
          f"{capacity['stream_steps_per_sec']:>12,.0f} steps/s = "
          f"{capacity['realtime_streams_50hz']:,} real-time 50 Hz sensors "
          f"(sustained: {capacity['sustained_realtime_50hz']})", flush=True)

    # achieved-vs-peak at the capacity point's measured aggregate rate,
    # against the launch/roofline.py hardware model (satellite of the
    # MXU-shaped kernel layout — reports both the real cell's FLOPs and
    # what the 128-lane padded layout actually issues)
    kern = Q15StreamStep(qp, backend=args.backend,
                         mxu=(args.backend == "pallas"))
    record = {
        "benchmark": "fleet_sharding",
        "model": "FastGRNN H=16 r_w=2 r_u=8, Q15 PTQ (566-byte class)",
        "backend": args.backend,
        "placement": cap_placement,
        "placements": resolved,
        "slots_per_shard": args.slots_per_shard,
        "window": 128,
        "sample_rate_hz": 50.0,
        "host": {"platform": platform.platform(),
                 "cpus": __import__("os").cpu_count(),
                 "jax": jax.__version__,
                 "devices": len(jax.devices()),
                 "device": str(jax.devices()[0])},
        "results": rows,
        "scaling_1_to_max_x": max(
            r["scaling_x"] for r in rows if r["placement"] == cap_placement),
        "scaling_by_placement": {
            p: max(r["scaling_x"] for r in rows if r["placement"] == p)
            for p in resolved},
        "capacity": capacity,
        "kernel_roofline": kern.roofline(capacity["stream_steps_per_sec"]),
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(obs.metrics.dumps() + "\n")
        print(f"wrote {args.metrics_out}")


if __name__ == "__main__":
    main()
