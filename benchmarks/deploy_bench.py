"""Deployment bench: export sizes, budget audits, qvm/C throughput, parity.

    PYTHONPATH=src python -m benchmarks.deploy_bench \
        [--out BENCH_deploy.json] [--windows 512] [--trained]

Emits a JSON perf+size record for the `repro.deploy` subsystem:

  * packed-image size breakdown + per-engine flash/SRAM budget audits
    against the avr / msp430 platform profiles (core/mcu.PLATFORMS);
  * qvm throughput: pure-integer emulated windows/s and stream-steps/s
    (batched over all windows in lockstep);
  * compiled-C throughput for both engines (host cc, includes pipe I/O);
  * the parity agreement matrix from repro.deploy.verify (bitwise float-C
    <-> oracle, bitwise int-C <-> qvm, argmax agreement everywhere);
  * the structural MCU latency model's per-step predictions for context
    (core/mcu — a fitted MODEL, not a measurement; labeled as such).

Default model is the deterministic random-init reference export (sizes
and throughput do not depend on training); ``--trained`` runs the pinned
parity-protocol model instead (slower: trains first).
"""
from __future__ import annotations

import argparse
import json
import platform as _platform
import tempfile
import time

import numpy as np

from repro.core import fastgrnn as fg, mcu
from repro.data import hapt
from repro.deploy import emit_c, verify
from repro.deploy.goldens import build_reference_model
from repro.deploy.image import size_report, audit_platforms
from repro.deploy.qvm import QVM


def bench_qvm(vm: QVM, xq: np.ndarray, repeats: int = 3) -> dict:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        vm.run_windows(xq)
        best = min(best, time.perf_counter() - t0)
    n, t = xq.shape[0], xq.shape[1]
    return {
        "windows": int(n),
        "windows_per_sec": round(n / best, 1),
        "stream_steps_per_sec": round(n * t / best, 1),
        "realtime_streams_50hz": int(n * t / best / 50.0),
    }


def bench_c(img, xq: np.ndarray, engine: str) -> dict:
    with tempfile.TemporaryDirectory() as td:
        t0 = time.perf_counter()
        binary = emit_c.compile_host(img, td, engine=engine)
        build_s = time.perf_counter() - t0
        cm = emit_c.CHostModel(binary, img.H, img.C, engine=engine)
        t0 = time.perf_counter()
        cm.predict_batch(xq)
        run_s = time.perf_counter() - t0
    n, t = xq.shape[0], xq.shape[1]
    return {
        "engine": engine,
        "cc_build_s": round(build_s, 3),
        "windows_per_sec": round(n / run_s, 1),
        "stream_steps_per_sec": round(n * t / run_s, 1),
    }


def mcu_model_context(cfg: fg.FastGRNNConfig) -> dict:
    """Fitted cycle-model predictions (NOT measurements; see core/mcu)."""
    return {
        "disclaimer": "structural cycle MODEL fitted to the paper's "
                      "measured endpoints — not a measurement",
        "per_step_ms": {
            "arduino_lut": round(1e3 * mcu.step_latency_s(cfg, mcu.ARDUINO), 3),
            "msp430_lut": round(1e3 * mcu.step_latency_s(cfg, mcu.MSP430), 3),
            "msp430_no_lut": round(1e3 * mcu.step_latency_s(
                cfg, mcu.MSP430, lut=False), 1),
        },
        "msp430_lut_speedup": round(mcu.lut_speedup(cfg, mcu.MSP430), 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_deploy.json")
    ap.add_argument("--windows", type=int, default=512)
    ap.add_argument("--trained", action="store_true")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="also dump a metrics_snapshot JSON: bench "
                         "counters/gauges plus the monitored qvm's "
                         "numeric-health series over the same windows")
    args = ap.parse_args()

    if args.trained:
        params, calib = verify.protocol_model()
        qp, _, img = build_reference_model(params=params, calib=calib)
        model_desc = f"trained parity protocol {verify.PROTOCOL}"
    else:
        qp, _, img = build_reference_model(seed=0)
        model_desc = "random-init reference export (seed 0)"

    test = hapt.load("test", n=args.windows)
    vm = QVM(img)
    xq = vm.quantize_input(test.windows)

    print("qvm bench ...", flush=True)
    qvm_rows = bench_qvm(vm, xq)
    c_rows = []
    if emit_c.find_cc():
        for engine in ("float", "int"):
            print(f"c {engine} bench ...", flush=True)
            c_rows.append(bench_c(img, xq, engine))
    print("parity ...", flush=True)
    parity = verify.run_parity(img, qp, test.windows, use_fp32=False)

    record = {
        "benchmark": "deploy_export",
        "model": model_desc,
        "host": {"platform": _platform.platform(),
                 "cc": emit_c.find_cc()},
        "image": size_report(img),
        "budgets": {e: audit_platforms(img, engine=e)
                    for e in ("float", "int")},
        "qvm": qvm_rows,
        "c_host": c_rows,
        "parity": {
            "n_windows": parity["n_windows"],
            "agreement": parity["agreement"],
            "pairwise": parity["pairwise"],
            "bitwise": parity["bitwise"],
        },
        "mcu_cycle_model": mcu_model_context(
            fg.FastGRNNConfig(rank_w=img.rank_w or None,
                              rank_u=img.rank_u or None)),
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")
    if args.metrics_out:
        from repro.obs import MetricsRegistry
        from repro.obs.numerics import NumericsMonitor
        reg = MetricsRegistry()
        reg.counter("bench.deploy.windows",
                    "windows benched per engine path").inc(len(xq))
        reg.gauge("bench.deploy.qvm.steps_per_sec", wallclock=True).set(
            qvm_rows["stream_steps_per_sec"])
        for r in c_rows:
            reg.gauge(f"bench.deploy.c_{r['engine']}.steps_per_sec",
                      wallclock=True).set(r["stream_steps_per_sec"])
        mon = NumericsMonitor()
        QVM(img, monitor=mon).run_windows(xq)
        mon.publish(reg)
        with open(args.metrics_out, "w") as f:
            f.write(reg.dumps() + "\n")
        print(f"wrote {args.metrics_out}")
    print(f"  qvm: {qvm_rows['stream_steps_per_sec']:,.0f} steps/s "
          f"({qvm_rows['realtime_streams_50hz']:,} live 50 Hz sensors)")
    for r in c_rows:
        print(f"  c[{r['engine']}]: {r['stream_steps_per_sec']:,.0f} steps/s")


if __name__ == "__main__":
    main()
