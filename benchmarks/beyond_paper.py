"""Beyond-paper extensions the paper itself proposes (Sec. VI-A / VI-E):

  * dual-rank static-vs-dynamic decomposition:
      U_eff = LowRank(r_u=4) + diag(alpha)   (+H params)
    vs the deployed r_u=8 and the plain r_u=4 ablation — the paper expects
    the diagonal residual to recover static-class accuracy at dynamic-class
    rank;
  * warm-up latency on LSTM/GRU at the paper's H=16 (Sec. VI-A: 'verifying
    this on LSTM/GRU baselines at matched parameter counts is an obvious
    follow-up') — is the ~1.5 s stabilization a FastGRNN artifact or a
    property of small gated recurrences generally?
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastgrnn as fg, pipeline as pl, warmup
from repro.models import baselines
from . import common


def dual_rank_decomposition():
    tr, te = common.data()
    rows = []
    for tag, cfg in [
        ("ru8", fg.FastGRNNConfig(rank_w=2, rank_u=8)),
        ("ru4", fg.FastGRNNConfig(rank_w=2, rank_u=4)),
        ("ru4_diag", fg.FastGRNNConfig(rank_w=2, rank_u=4, diag_residual=True)),
    ]:
        params = common.train_cached(cfg, f"dual_{tag}", seed=0)
        pred = pl.predict_fp32(params, te.windows)
        f1 = pl.macro_f1(te.labels, pred)
        per = pl.per_class_f1(te.labels, pred)
        static = np.mean(per[3:])          # SITTING/STANDING/LAYING
        dynamic = np.mean(per[:3])
        rows.append(common.csv_row(
            f"dualrank_{tag}", "",
            f"params={cfg.cell_param_count()};f1={f1:.3f};"
            f"static_f1={static:.3f};dynamic_f1={dynamic:.3f}"))
    return rows


def _rnn_warmup(step_fn, params, head_w, head_b, windows, carry0_fn):
    preds = []
    for w in windows:
        xs = jnp.asarray(w[:, None, :])
        traj = baselines.rnn_run(step_fn, params, xs, carry0_fn())
        logits = np.asarray(traj[:, 0]) @ head_w + head_b
        preds.append(np.argmax(logits, -1))
    return warmup.characterize(np.stack(preds))


def warmup_lstm_gru():
    """Train tiny LSTM/GRU HAR models and run the paper's warm-up protocol."""
    tr, te = common.data()
    rows = []
    n_tr = min(1500, len(tr.labels))
    xs_all = np.transpose(tr.windows[:n_tr], (1, 0, 2))
    ys_all = tr.labels[:n_tr]

    for name, init_fn, step_fn, carry0 in [
        ("lstm", baselines.lstm_init, baselines.lstm_step,
         lambda: (jnp.zeros((1, 16)), jnp.zeros((1, 16)))),
        ("gru", baselines.gru_init, baselines.gru_step,
         lambda: jnp.zeros((1, 16))),
    ]:
        key = jax.random.PRNGKey(0)
        params = init_fn(key)
        head = {"w": 0.1 * jax.random.normal(key, (16, 6)),
                "b": jnp.zeros(6)}

        def loss(p, h, xs, ys):
            traj = baselines.rnn_run(step_fn, p, xs,
                                     jax.tree.map(lambda z: jnp.zeros(
                                         (xs.shape[1], 16)), carry0())
                                     if name == "lstm" else
                                     jnp.zeros((xs.shape[1], 16)))
            logits = traj[-1] @ h["w"] + h["b"]
            lp = jax.nn.log_softmax(logits, -1)
            return -jnp.take_along_axis(lp, ys[:, None], axis=-1).mean()

        valgrad = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))
        rng = np.random.default_rng(0)
        for epoch in range(25):
            order = rng.permutation(n_tr)
            for i in range(0, n_tr - 64, 64):
                j = order[i:i + 64]
                l, (gp, gh) = valgrad(params, head,
                                      jnp.asarray(xs_all[:, j]),
                                      jnp.asarray(ys_all[j]))
                params = jax.tree.map(lambda w, g: w - 3e-3 * g, params, gp)
                head = jax.tree.map(lambda w, g: w - 3e-3 * g, head, gh)
        st = _rnn_warmup(step_fn, params,
                         np.asarray(head["w"]), np.asarray(head["b"]),
                         te.windows[:60], carry0)
        rows.append(common.csv_row(
            f"warmup_{name}_h16", "",
            f"median={st.median_samples:.0f};iqr={st.iqr_lo:.0f}-{st.iqr_hi:.0f};"
            f"worst={st.worst_case};n={st.n_windows}"))
    return rows
