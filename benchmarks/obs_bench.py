"""Observability overhead benchmark: what does telemetry cost the fleet?

    PYTHONPATH=src python -m benchmarks.obs_bench \
        [--out BENCH_obs.json] [--shards 8] [--slots-per-shard 16384] \
        [--windows 2] [--smoke]

Three measurements, one record (the PR's acceptance budgets):

* **Baseline vs traced throughput** — the capacity fleet (default
  8 x 16384 = 131,072 resident streams) stepped to completion with the
  default :data:`~repro.obs.NULL_OBS` (NullTracer path — must stay
  within the 2 % band of the committed ``BENCH_fleet.json`` capacity
  number) and with the full bundle (tracer + metrics + flight recorder)
  whose overhead must stay under 10 %.  Runs are **interleaved
  median-of-N** (``--reps``, default 3): shared-container throughput
  jitters far more than the budgets being judged, so the record also
  carries ``measured_noise_pct`` (rep spread) and a delta below the
  noise floor is not counted as a budget violation.
* **Tick-phase breakdown + deadline-miss rate** — from the traced
  capacity run: per-phase p50/p99 (``Tracer.phase_stats``) and the 50 Hz
  deadline-miss counters at 131k streams
  (``fleet.deadline_miss_stream_ticks`` / total stream-ticks).
* **Flight-recorder byte-stability** — two identical runs under the
  full phase x shard ``crash_matrix`` fault schedule must produce
  byte-identical ``dumps(deterministic=True)``.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import jax
import numpy as np

from repro.core import fastgrnn as fg
from repro.core.quantization import quantize_params, QuantConfig
from repro.data import hapt
from repro.obs import Observability
from repro.serve.fleet import FleetConfig, FleetEngine, crash_matrix
from repro.serve.streaming import StreamingConfig


def _build(qp, shards: int, slots: int, windows: int, obs, *,
           snapshot_every=None, faults=None) -> FleetEngine:
    ring = 128 * windows
    stream = StreamingConfig(max_slots=slots, backend="jit",
                             batch_events=True, ring_capacity=ring,
                             max_ring_capacity=ring)
    return FleetEngine(qp, FleetConfig(
        shards=shards, stream=stream, max_pending_per_shard=0,
        placement="host", snapshot_every=snapshot_every),
        obs=obs, faults=faults)


def _fill(fleet, src, n_streams: int, windows: int) -> None:
    total = 128 * windows
    for i in range(n_streams):
        fleet.attach(f"s{i}", total_steps=total)
        fleet.feed(f"s{i}", np.tile(src[i % len(src)], (windows, 1)))


def _timed_run(qp, src, shards: int, slots: int, windows: int,
               obs) -> dict:
    n_streams = shards * slots
    fleet = _build(qp, shards, slots, windows, obs)
    _fill(fleet, src, n_streams, windows)
    total = 128 * windows
    fleet.step()                                 # warm-up tick (jit compile)
    tick_s = []
    t_start = time.perf_counter()
    for _ in range(total - 1):
        t0 = time.perf_counter()
        fleet.step()
        tick_s.append(time.perf_counter() - t0)
    elapsed = time.perf_counter() - t_start
    stats = fleet.stats()
    assert stats["completed"] == n_streams, stats
    steps = n_streams * (total - 1)
    tick_ms = np.asarray(tick_s) * 1e3
    return {
        "concurrent_streams": n_streams,
        "ticks": len(tick_s),
        "stream_steps_per_sec": round(steps / elapsed, 1),
        "p50_ms": round(float(np.percentile(tick_ms, 50)), 4),
        "p99_ms": round(float(np.percentile(tick_ms, 99)), 4),
        "stream_ticks": steps,
    }


def _flight_stability(qp, input_dim: int, shards: int = 4) -> dict:
    """Two identical crash-matrix runs -> byte-identical deterministic
    flight dumps (the crash-forensics determinism gate)."""
    rng = np.random.default_rng(7)
    streams = {f"st{i:03d}": rng.standard_normal((300, input_dim))
               .astype(np.float32) for i in range(16)}

    def run() -> tuple[str, int]:
        obs = Observability.full()
        fleet = _build(qp, shards, 8, 3, obs, snapshot_every=32,
                       faults=crash_matrix(shards))
        for sid, w in streams.items():
            fleet.attach(sid, w, total_steps=len(w))
        fleet.drain()
        return obs.recorder.dumps(deterministic=True), obs.recorder.n_crashes

    dump_a, crashes = run()
    dump_b, _ = run()
    return {
        "shards": shards,
        "crashes": crashes,
        "dump_bytes": len(dump_a),
        "byte_stable": dump_a == dump_b,
    }


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="BENCH_obs.json")
    parser.add_argument("--shards", type=int, default=8)
    parser.add_argument("--slots-per-shard", type=int, default=16384)
    parser.add_argument("--windows", type=int, default=3,
                        help="128-sample windows per stream (default "
                             "matches fleet_bench's capacity geometry so "
                             "the null-vs-BENCH_fleet gate is apples-to-"
                             "apples)")
    parser.add_argument("--reps", type=int, default=3,
                        help="interleaved baseline/traced repetitions "
                             "(median-of-N)")
    parser.add_argument("--fleet-bench", default="BENCH_fleet.json",
                        help="committed fleet capacity record to compare "
                             "the NullTracer run against")
    parser.add_argument("--smoke", action="store_true",
                        help="CI configuration: tiny fleet, 1 window")
    args = parser.parse_args()
    if args.smoke:
        args.shards, args.slots_per_shard, args.windows = 2, 256, 1
        args.reps = 1

    cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    qp = quantize_params(fg.init_params(cfg, jax.random.PRNGKey(0)),
                         QuantConfig())
    src = hapt.load("test", n=256).windows
    n_streams = args.shards * args.slots_per_shard

    # interleaved A/B: baseline and traced alternate within one process,
    # so slow drift in container load hits both arms equally; medians
    # (not means) absorb the occasional noisy-neighbour outlier rep
    base_runs: list[dict] = []
    traced_runs: list[tuple[dict, Observability]] = []
    reps = max(1, args.reps)
    for rep in range(reps):
        print(f"rep {rep + 1}/{reps} baseline (NULL_OBS): "
              f"{n_streams:,} streams ...", flush=True)
        base_runs.append(_timed_run(qp, src, args.shards,
                                    args.slots_per_shard, args.windows,
                                    obs=None))
        print(f"  {base_runs[-1]['stream_steps_per_sec']:>14,.0f} steps/s  "
              f"p50 {base_runs[-1]['p50_ms']:.3f} ms", flush=True)
        print(f"rep {rep + 1}/{reps} traced (full bundle): "
              f"{n_streams:,} streams ...", flush=True)
        ob = Observability.full(capacity=8192)
        traced_runs.append((_timed_run(qp, src, args.shards,
                                       args.slots_per_shard, args.windows,
                                       obs=ob), ob))
        print(f"  {traced_runs[-1][0]['stream_steps_per_sec']:>14,.0f} "
              f"steps/s  p50 {traced_runs[-1][0]['p50_ms']:.3f} ms",
              flush=True)
    base_runs.sort(key=lambda r: r["stream_steps_per_sec"])
    baseline = base_runs[len(base_runs) // 2]
    traced_runs.sort(key=lambda t: t[0]["stream_steps_per_sec"])
    traced, obs = traced_runs[len(traced_runs) // 2]
    rates = ([r["stream_steps_per_sec"] for r in base_runs]
             + [run["stream_steps_per_sec"] for run, _ in traced_runs])
    noise_pct = round(100.0 * (max(rates) - min(rates))
                      / float(np.median(rates)), 2)

    snap = obs.metrics.snapshot()
    miss_stream_ticks = snap["counters"][
        "fleet.deadline_miss_stream_ticks"]
    deadline = {
        "deadline_ms": 20.0,           # 50 Hz real-time budget
        "concurrent_streams": n_streams,
        "miss_ticks": snap["counters"]["fleet.deadline_miss_ticks"],
        "miss_stream_ticks": miss_stream_ticks,
        "stream_ticks": traced["stream_ticks"],
        "miss_rate": round(miss_stream_ticks / traced["stream_ticks"], 6),
    }
    phases = {name: {k: st[k] for k in ("count", "p50_us", "p99_us")}
              for name, st in obs.tracer.phase_stats().items()}

    overhead_pct = round(
        100.0 * (1 - traced["stream_steps_per_sec"]
                 / baseline["stream_steps_per_sec"]), 2)
    budgets = {
        "traced_overhead_pct": overhead_pct,
        "traced_budget_pct": 10.0,
        "traced_within_budget": overhead_pct <= 10.0,
        "null_budget_pct": 2.0,
        # rep spread across all interleaved runs: the host's own
        # run-to-run jitter, recorded so budget deltas can be read
        # against the measurement's actual resolution
        "measured_noise_pct": noise_pct,
    }
    # NullTracer (= the default path) vs the committed fleet capacity
    # number, when this run used the same geometry (stream count AND
    # tick count — a different windows-per-stream setting amortizes
    # fixed costs differently and is not a valid comparison).  A delta
    # below this session's measured rep spread is not evidence of a
    # regression — the comparison crosses processes, so it inherits the
    # full inter-run noise, and the budget gate saturates at that floor.
    if os.path.exists(args.fleet_bench):
        with open(args.fleet_bench) as f:
            cap = json.load(f).get("capacity", {})
        if (cap.get("concurrent_streams") == n_streams
                and cap.get("ticks") == baseline["ticks"]):
            ref = cap["stream_steps_per_sec"]
            delta = round(
                100.0 * (1 - baseline["stream_steps_per_sec"] / ref), 2)
            budgets["null_vs_fleet_bench_pct"] = delta
            budgets["null_within_budget"] = delta <= max(2.0, noise_pct)
    print(f"traced overhead: {overhead_pct:+.2f}% "
          f"(budget 10%, rep noise {noise_pct:.1f}%); deadline misses at "
          f"{n_streams:,} streams: "
          f"{deadline['miss_rate'] * 100:.4f}%", flush=True)

    input_dim = 3
    flight = _flight_stability(qp, input_dim,
                               shards=2 if args.smoke else 4)
    print(f"flight recorder: {flight['crashes']} crashes, "
          f"{flight['dump_bytes']:,} B deterministic dump, "
          f"byte_stable={flight['byte_stable']}", flush=True)

    record = {
        "benchmark": "obs_overhead",
        "model": "FastGRNN H=16 r_w=2 r_u=8, Q15 PTQ (566-byte class)",
        "backend": "jit",
        "window": 128,
        "sample_rate_hz": 50.0,
        "host": {"platform": platform.platform(),
                 "cpus": os.cpu_count(),
                 "jax": jax.__version__,
                 "device": str(jax.devices()[0])},
        "config": {"shards": args.shards,
                   "slots_per_shard": args.slots_per_shard,
                   "windows": args.windows,
                   "concurrent_streams": n_streams},
        "baseline": baseline,
        "traced": traced,
        "budgets": budgets,
        "phases": phases,
        "deadline": deadline,
        "flight_recorder": flight,
    }
    with open(args.out, "w") as f:
        json.dump(record, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
