"""Paper Tables I-V + Fig. 6: accuracy across the L-S-Q pipeline.

All F1 numbers are on synthetic HAPT (DESIGN.md Sec. 8); the deliverable
is the paper's RELATIVE structure: low-rank ~ full-rank, sparsity costs a
little, calibrated Q15 is lossless, naive Q15 collapses.
"""
from __future__ import annotations

import numpy as np

from repro.core import fastgrnn as fg, pipeline as pl, compression as comp
from repro.core.lut import lut_sigmoid, lut_tanh
from repro.models import baselines
import jax

from . import common


def _f1(params, te, n=None):
    w = te.windows[:n] if n else te.windows
    l = te.labels[:n] if n else te.labels
    return pl.macro_f1(l, pl.predict_fp32(params, w))


def table1_hidden_size():
    """Table I: H=16 vs H=32 full-rank (H=32 larger yet not better)."""
    rows = []
    tr, te = common.data()
    for H, tag in [(16, "t1_h16"), (32, "t1_h32")]:
        cfg = fg.FastGRNNConfig(hidden_dim=H)
        params = common.train_cached(cfg, tag, seed=0)
        f1 = _f1(params, te)
        n = cfg.cell_param_count() + cfg.head_param_count()
        rows.append(common.csv_row(f"table1_H{H}", "",
                                   f"f1={f1:.3f};params={n}"))
    return rows


def _lsq_models(seed: int):
    """Train the three pipeline stages for one seed."""
    full = common.train_cached(fg.FastGRNNConfig(), f"t2_full", seed)
    lr_cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
    lr = common.train_cached(lr_cfg, f"t2_lr", seed)
    iht = comp.IHTConfig(target_sparsity=0.5, ramp_epochs=common.EPOCHS // 2)
    sp = common.train_cached(lr_cfg, f"t2_sparse", seed, iht=iht)
    return full, lr, sp


def table2_lsq_pipeline():
    """Table II: cumulative F1 + nonzero + bytes per stage (seed 0)."""
    tr, te = common.data()
    full, lr, sp = _lsq_models(0)
    rt = pl.deploy(sp, tr.windows[:5])
    icfg = comp.IHTConfig(target_sparsity=0.5)
    masks = comp.compute_masks(sp, icfg, 0.5)
    nz = comp.deployed_param_count(sp, masks)
    rows = [
        common.csv_row("table2_full_rank", "", f"f1={_f1(full, te):.3f};nonzero=440;bytes=1760"),
        common.csv_row("table2_low_rank", "", f"f1={_f1(lr, te):.3f};nonzero=430;bytes=1720"),
        common.csv_row("table2_sparse", "", f"f1={_f1(sp, te):.3f};nonzero={nz};bytes={nz*4}"),
        common.csv_row("table2_q15_deployed", "",
                       f"f1={pl.macro_f1(te.labels, rt.predict_batch(te.windows)):.3f};"
                       f"nonzero={nz};bytes={nz*2}"),
    ]
    return rows


def table3_per_seed():
    """Table III: per-seed LR/sparse/Q15 F1 + FP32-vs-Q15 agreement."""
    tr, te = common.data()
    rows = []
    f1s = []
    for seed in common.SEEDS:
        _, lr, sp = _lsq_models(seed)
        rt = pl.deploy(sp, tr.windows[:5])
        qpred = rt.predict_batch(te.windows)
        fpred = pl.predict_fp32(sp, te.windows)
        f1_lr, f1_sp = _f1(lr, te), _f1(sp, te)
        f1_q = pl.macro_f1(te.labels, qpred)
        agree = pl.agreement(qpred, fpred)
        f1s.append(f1_q)
        rows.append(common.csv_row(
            f"table3_seed{seed}", "",
            f"lr_f1={f1_lr:.3f};sparse_f1={f1_sp:.3f};q15_f1={f1_q:.3f};"
            f"agree={agree:.4f}"))
    rows.append(common.csv_row(
        "table3_mean_std", "",
        f"q15_f1_mean={np.mean(f1s):.3f};std={np.std(f1s):.3f}"))
    return rows


def table4_param_footprint():
    """Table IV: cell-only parameter counts + measured MLP baseline F1."""
    tr, te = common.data()
    import jax.numpy as jnp
    p = baselines.mlp_init(jax.random.PRNGKey(0))
    # quick MLP training
    import jax as _jax
    opt_lr = 1e-3
    loss_g = _jax.jit(_jax.value_and_grad(baselines.mlp_loss))
    rng = np.random.default_rng(0)
    xs_all = np.transpose(tr.windows, (1, 0, 2))
    for epoch in range(30):
        order = rng.permutation(len(tr.labels))
        for i in range(0, len(order) - 64, 64):
            j = order[i:i + 64]
            l, g = loss_g(p, jnp.asarray(xs_all[:, j]), jnp.asarray(tr.labels[j]))
            p = _jax.tree.map(lambda w, gg: w - opt_lr * gg, p, g)
    preds = np.argmax(np.asarray(baselines.mlp_forward(
        p, jnp.asarray(np.transpose(te.windows, (1, 0, 2))))), -1)
    mlp_f1 = pl.macro_f1(te.labels, preds)
    return [
        common.csv_row("table4_mlp", "", f"params=12518;f1={mlp_f1:.3f}"),
        common.csv_row("table4_lstm", "", f"params={baselines.lstm_param_count()};f1=theoretical"),
        common.csv_row("table4_gru", "", f"params={baselines.gru_param_count()};f1=theoretical"),
        common.csv_row("table4_fastgrnn_cell", "",
                       f"params={fg.FastGRNNConfig().cell_param_count()}"),
        common.csv_row("table4_fastgrnn_L", "",
                       f"params={fg.FastGRNNConfig(rank_w=2, rank_u=8).cell_param_count()}"),
        common.csv_row("table4_fastgrnn_LSQ", "", "params=181;plus_head=283"),
    ]


def table5_quant_modes():
    """Table V / Fig. 5: quantization-mode ablation on seed 0."""
    tr, te = common.data()
    _, _, sp = _lsq_models(0)
    f_fp32 = _f1(sp, te)
    lut_pred = pl.predict_fp32(sp, te.windows,
                               sigma=lambda x: lut_sigmoid(x, "nearest"),
                               tanh=lambda x: lut_tanh(x, "nearest"))
    rt_lut = pl.deploy(sp, tr.windows[:5])                      # deployed
    rt_naive = pl.deploy(sp, tr.windows[:5], naive_activations=True)
    rt_cal = pl.deploy(sp, tr.windows[:5], quantize_activations=True)
    rows = [
        common.csv_row("table5_float32", "", f"f1={f_fp32:.3f};role=reference"),
        common.csv_row("table5_q15w_fp32acts_lut", "",
                       f"f1={pl.macro_f1(te.labels, rt_lut.predict_batch(te.windows)):.3f};role=deployed"),
        common.csv_row("table5_q15w_naive_acts", "",
                       f"f1={pl.macro_f1(te.labels, rt_naive.predict_batch(te.windows)):.3f};role=collapse"),
        common.csv_row("table5_q15w_calibrated_acts", "",
                       f"f1={pl.macro_f1(te.labels, rt_cal.predict_batch(te.windows)):.3f};role=counterfactual"),
    ]
    return rows


def fig6_per_class():
    """Fig. 6: per-class F1 across stages (seed 0)."""
    tr, te = common.data()
    full, lr, sp = _lsq_models(0)
    rt = pl.deploy(sp, tr.windows[:5])
    rows = []
    from repro.data.hapt import CLASSES
    stages = {
        "full": pl.predict_fp32(full, te.windows),
        "low_rank": pl.predict_fp32(lr, te.windows),
        "sparse": pl.predict_fp32(sp, te.windows),
        "q15": rt.predict_batch(te.windows),
    }
    for stage, pred in stages.items():
        per = pl.per_class_f1(te.labels, pred)
        detail = ";".join(f"{c}={v:.2f}" for c, v in zip(CLASSES, per))
        rows.append(common.csv_row(f"fig6_{stage}", "", detail))
    return rows
