"""End-to-end driver (the paper's kind: train-then-deploy on-device HAR).

Reproduces the full Fig.-1 flow at paper scale:
  float training (100 epochs) -> low-rank -> IHT sparsity (cubic ramp,
  frozen finetune) -> Q15 + activation calibration -> deterministic
  deploy -> 50 Hz streaming simulation with warm-up characterization and
  the MCU latency/energy model report.

    PYTHONPATH=src python examples/har_end_to_end.py [--fast]
"""
import argparse
import time

import numpy as np

from repro.core import fastgrnn as fg, pipeline as pl, compression as comp
from repro.core import mcu, energy as en, warmup
from repro.data import hapt
from repro.configs import fastgrnn_har as paper

parser = argparse.ArgumentParser()
parser.add_argument("--fast", action="store_true",
                    help="reduced data/epochs (CI-sized)")
parser.add_argument("--seed", type=int, default=0)
args = parser.parse_args()

n_train = 2500 if args.fast else None
epochs = 50 if args.fast else paper.EPOCHS
train = hapt.load("train", n=n_train)
test = hapt.load("test", n=800 if args.fast else None)

print(f"== training FastGRNN (H=16, r_w=2, r_u=8, s=0.5) "
      f"{epochs} epochs on {len(train.labels)} windows ==")
iht = comp.IHTConfig(target_sparsity=0.5, ramp_epochs=epochs // 2)
t0 = time.time()
res = pl.train_fastgrnn(paper.CELL, train.windows, train.labels,
                        epochs=epochs, seed=args.seed, iht=iht,
                        batch_size=paper.BATCH_SIZE, lr=paper.LEARNING_RATE)
print(f"trained in {time.time()-t0:.0f}s")

nz = comp.deployed_param_count(res.params, res.masks)
print(f"deployed parameters: {nz} ({nz*2} bytes at Q15)")

print("== deploying: Q15 + 5-minibatch activation calibration ==")
rt = pl.deploy(res.params, train.windows[:5])
fp32 = pl.predict_fp32(res.params, test.windows)
q15 = rt.predict_batch(test.windows)
print(f"FP32 macro-F1 : {pl.macro_f1(test.labels, fp32):.4f}")
print(f"Q15  macro-F1 : {pl.macro_f1(test.labels, q15):.4f}")
print(f"agreement     : {pl.agreement(fp32, q15)*100:.2f}% "
      f"on {len(test.labels)} windows")

print("== 50 Hz streaming simulation: warm-up latency (paper Sec. VI-A) ==")
preds = []
for w in test.windows[:100]:
    _, traj = rt.run_window(w, return_trajectory=True)
    step_logits = traj @ np.asarray(rt._w["head_w"]) + np.asarray(rt._head_b)
    preds.append(np.argmax(step_logits, -1))
stats = warmup.characterize(np.stack(preds))
print(f"warm-up: {stats.row()}")

print("== MCU latency/energy model (fitted to the paper's measurements) ==")
for plat in (mcu.ARDUINO, mcu.MSP430):
    t = mcu.step_latency_s(paper.CELL, plat, lut=True)
    print(f"{plat.name:32s}: {t*1e3:5.2f} ms/sample "
          f"({mcu.budget_use(paper.CELL, plat)*100:.0f}% of 20 ms budget), "
          f"LUT speedup {mcu.lut_speedup(paper.CELL, plat):.1f}x")
print(f"energy: {en.LUT_BUILD.e_inference_uj:.0f} uJ/inference, "
      f"{en.LUT_BUILD.e_window_mj:.1f} mJ/window, "
      f"battery {en.LUT_BUILD.battery_hours(False):.0f} h streaming")
