"""Serving demo: batched prefill + decode with the L-S-Q quantized path.

    PYTHONPATH=src python examples/serve_demo.py --arch mamba2-780m
    PYTHONPATH=src python examples/serve_demo.py --shards 4

Default mode runs a reduced LM through the serving engine twice — bf16
weights and int8 (Q7) per-tensor quantized weights (the paper's Q stage
at LM scale, via the same ``repro.compress.quantize_tree`` pass the
engine uses internally) — and reports tokens generated, agreement between
the two paths, the per-tree weight-byte saving, and the analytic HBM-byte
saving for the full config.

``--shards N`` (N > 1) instead drives the *sensor-fleet* serving path:
the same entry point stands up a sharded ``serve/fleet.FleetEngine``
(N per-shard slot schedulers, rendezvous routing, one fused Q15 kernel
dispatch per tick), classifies a batch of HAPT windows through it with a
forced mid-stream migration, and checks the fleet's predictions
bit-identically against the scalar QRuntime reference.
"""
import argparse

import jax
import numpy as np

import repro.configs as C
from repro.compress import tree_size_report
from repro.models import registry
from repro.serve.engine import Engine, ServeConfig

parser = argparse.ArgumentParser()
parser.add_argument("--arch", default="deepseek-7b", choices=list(C.ARCHS))
parser.add_argument("--batch", type=int, default=4)
parser.add_argument("--new-tokens", type=int, default=24)
parser.add_argument("--shards", type=int, default=1,
                    help="> 1: demo the sharded Q15 sensor-fleet path "
                         "(serve/fleet) instead of the LM engine")
parser.add_argument("--metrics-out", default=None,
                    help="attach the repro.obs telemetry bundle (tracer + "
                         "metrics) and write the metrics snapshot JSON "
                         "(schema 'metrics_snapshot') to this path")
args = parser.parse_args()


def _make_obs():
    if not args.metrics_out:
        return None
    from repro.obs import Observability
    return Observability.full()


def _write_metrics(obs) -> None:
    if obs is None:
        return
    with open(args.metrics_out, "w") as f:
        f.write(obs.metrics.dumps() + "\n")
    phases = ", ".join(sorted(obs.tracer.phase_stats())) or "none"
    print(f"wrote {args.metrics_out} (traced phases: {phases})")


def fleet_demo(n_shards: int) -> None:
    from repro.core import fastgrnn as fg
    from repro.core.qruntime import QRuntime
    from repro.core.quantization import quantize_params, QuantConfig
    from repro.data import hapt
    from repro.serve.fleet import FleetConfig, FleetEngine
    from repro.serve.streaming import StreamingConfig

    obs = _make_obs()
    qp = quantize_params(
        fg.init_params(fg.FastGRNNConfig(rank_w=2, rank_u=8),
                       jax.random.PRNGKey(0)), QuantConfig())
    windows = hapt.load("test", n=96).windows
    fleet = FleetEngine(qp, FleetConfig(
        shards=n_shards, stream=StreamingConfig(max_slots=16)), obs=obs)
    for i, w in enumerate(windows):
        fleet.attach(f"sensor-{i}", w, total_steps=len(w))
    for _ in range(40):                      # advance mid-window...
        fleet.step()
    moved = fleet.migrate("sensor-0")        # ...then live-migrate one
    dst = fleet.shard_of("sensor-0")
    events = fleet.drain()
    preds = {}
    for e in events:
        for ev in (e.events() if hasattr(e, "events") else [e]):
            preds[ev.stream_id] = ev.prediction
    ref = QRuntime(qp).predict_batch(windows)
    agree = float(np.mean([preds[f"sensor-{i}"] == ref[i]
                           for i in range(len(windows))]))
    st = fleet.stats()
    print(f"fleet: {st['shards']} shards x "
          f"{st['per_shard'][0]['max_slots']} slots, "
          f"{st['completed']} streams classified, "
          f"{st['migrations']} live migration(s) "
          f"(sensor-0 re-attached {moved!r} on shard {dst})")
    print(f"scheduler roll-up: {st['scheduler']['admissions']} admissions, "
          f"{st['scheduler']['spills']} spills, "
          f"{st['scheduler']['evictions']} evictions across "
          f"{st['shards']} per-shard schedulers")
    print(f"bit-exactness vs scalar QRuntime: {agree * 100:.1f}% "
          f"({'OK' if agree == 1.0 else 'MISMATCH'})")
    _write_metrics(obs)


if args.shards > 1:
    fleet_demo(args.shards)
    raise SystemExit(0)

full = C.get(args.arch)
if not full.has_decode:
    raise SystemExit(f"{args.arch} is encoder-only: no decode path")
cfg = C.reduced(full, compute_dtype="float32", param_dtype="float32")
params = registry.init(cfg, jax.random.PRNGKey(0))
prompts = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                            (args.batch, 12))

obs = _make_obs()
fp = Engine(cfg, params, ServeConfig(max_len=64), obs=obs)
q8 = Engine(cfg, params, ServeConfig(max_len=64, quant_bits=8))
out_fp = fp.generate(prompts, max_new=args.new_tokens)
out_q8 = q8.generate(prompts, max_new=args.new_tokens)
agree = float((out_fp == out_q8).mean())
print(f"generated {out_fp.shape[1]} tokens x {args.batch} sequences")
sched = fp.stats()["scheduler"]
print(f"scheduler: {sched['admissions']} admissions, "
      f"{sched['recycles']} recycles, {sched['spills']} spills "
      f"(continuous batching via serve/scheduler.py)")
print(f"bf16-vs-int8 token agreement: {agree*100:.1f}% "
      f"(greedy, random-init model — trained models track much closer)")

# the engine quantized through repro.compress.quantize_tree (the single
# home of the PTQ math); audit the quantized pytree it actually serves
srep = tree_size_report(q8.qparams, bits=8)
print(f"quantized tree: {srep['quantized_params']} int8 params, "
      f"{srep['weight_bytes_quantized']/1e6:.2f} MB vs "
      f"{srep['weight_bytes_bf16']/1e6:.2f} MB bf16 "
      f"({srep['compression_ratio']:.2f}x)")

n = registry.param_count(full)
print(f"full {args.arch}: {n/1e9:.2f}B params -> weight bytes/decode-step "
      f"{n*2/1e9:.2f} GB (bf16) vs {n/1e9:.2f} GB (int8): the decode "
      f"memory-roofline term halves (see EXPERIMENTS.md Sec. Perf)")
_write_metrics(obs)
