"""Export a calibrated FastGRNN to a deployable MCU artifact, end to end.

    PYTHONPATH=src python examples/export_mcu.py [--outdir export_out]
        [--trained] [--windows 64]

Pipeline (the paper's Fig. 1 deployment half, now executable):

  1. model     — low-rank FastGRNN (H=16, r_w=2, r_u=8) + Q15 PTQ
                 (random-init by default; ``--trained`` trains first);
  2. calibrate — Sec. III-D deploy calibration (input, low-rank
                 intermediates, pre-activation, hidden, logit scales);
  3. pack      — deterministic versioned weight image (``model.fgrn``),
                 size-audited against the AVR + MSP430 budgets;
  4. emit      — C translation units for all three targets x both
                 engines (float = the paper's deployed arithmetic,
                 int = the multiplier-less pure-integer path);
  5. verify    — compile the host target with cc and check parity on a
                 window batch: float C bit-identical to the oracle,
                 int C bit-identical to the qvm emulator.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

from repro.data import hapt
from repro.deploy import emit_c, verify
from repro.deploy.goldens import build_reference_model
from repro.deploy.image import audit_platforms, export_model, size_report
from repro.deploy.qvm import QVM
from repro.core.qruntime import QRuntime, calibrate_deploy
from repro.core.quantization import QuantConfig, quantize_params


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="export_out")
    ap.add_argument("--trained", action="store_true",
                    help="train the pinned parity-protocol model first")
    ap.add_argument("--windows", type=int, default=64,
                    help="parity-check windows")
    args = ap.parse_args()

    # 1+2: model + deploy calibration -> packed image
    if args.trained:
        params, calib = verify.protocol_model()
        qp = quantize_params(params, QuantConfig())
        act_scales = calibrate_deploy(QRuntime(qp), calib)
        from repro.deploy.image import build_image
        img = build_image(qp, act_scales)
    else:
        qp, act_scales, img = build_reference_model(seed=0)

    os.makedirs(args.outdir, exist_ok=True)
    img2, blob = export_model(qp, act_scales,
                              os.path.join(args.outdir, "model.fgrn"))
    assert img2.to_bytes() == img.to_bytes()
    print(f"packed image: {len(blob)} bytes -> {args.outdir}/model.fgrn")
    rep = size_report(img)
    print(f"  weights {rep['weight_bytes']} B (paper class: 566 B), "
          f"LUTs f32/int16 {rep['lut_bytes']['float_engine']}/"
          f"{rep['lut_bytes']['int_engine']} B")

    # 3: budget audit (raises if the image cannot be flashed)
    for engine in ("float", "int"):
        audit = audit_platforms(img, ("avr", "msp430"), engine=engine)
        for key, a in audit.items():
            print(f"  [{engine:5s}] {key:6s}: flash {a['image_bytes']}/"
                  f"{a['flash_capacity'] - a['code_reserve']} B, "
                  f"sram {a['sram_needed']}/{a['sram_capacity']} B  OK")

    # 4: emit C for every target x engine
    for target in ("avr", "msp430", "host"):
        for engine in ("float", "int"):
            d = os.path.join(args.outdir, target, engine)
            paths = emit_c.write_sources(img, d, target=target, engine=engine)
            print(f"  emitted {target}/{engine}: "
                  f"{', '.join(os.path.basename(p) for p in paths)}")

    # 5: host parity
    if emit_c.find_cc() is None:
        print("no C compiler on PATH — skipping the compile+parity check")
        return
    windows = hapt.load("test", n=args.windows).windows
    report = verify.run_parity(img, qp, windows, use_fp32=False)
    print("parity over", report["n_windows"], "windows:")
    for k, v in report["bitwise"].items():
        print(f"  bitwise {k}: {'OK' if v else 'MISMATCH'}")
    for k, v in report["pairwise"].items():
        print(f"  argmax {k}: {v['agree']:.4f}")
    with open(os.path.join(args.outdir, "parity.json"), "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.outdir}/parity.json")


if __name__ == "__main__":
    main()
