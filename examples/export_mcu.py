"""Export a calibrated FastGRNN to a deployable MCU artifact, end to end.

    PYTHONPATH=src python examples/export_mcu.py [--outdir export_out]
        [--trained] [--windows 64] [--bits 15]

Pipeline (the paper's Fig. 1 deployment half, now one artifact end to end):

  1. model     — low-rank FastGRNN (H=16, r_w=2, r_u=8)
                 (random-init by default; ``--trained`` trains first);
  2. compress  — the composable pass pipeline: ``QuantizePTQ`` (Q15, or
                 Q7 with ``--bits 7``) -> ``CalibrateActivations``
                 (Sec. III-D deploy scopes: input, low-rank
                 intermediates, pre-activation, hidden, logit scales) ->
                 ``PackLUT``, all recorded as provenance on ONE versioned
                 `ModelArtifact` (saved as ``model.fgar``);
  3. pack      — lower the artifact to the deterministic wire image
                 (``model.fgrn``), size-audited against the AVR + MSP430
                 budgets;
  4. emit      — C translation units for all three targets x both
                 engines (float = the paper's deployed arithmetic,
                 int = the multiplier-less pure-integer path);
  5. verify    — compile the host target with cc and check parity on a
                 window batch: float C bit-identical to the oracle,
                 int C bit-identical to the qvm emulator.
"""
from __future__ import annotations

import argparse
import json
import os

from repro.data import hapt
from repro.deploy import emit_c, verify
from repro.deploy.goldens import build_reference_artifact
from repro.deploy.image import audit_platforms, build_image, size_report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="export_out")
    ap.add_argument("--trained", action="store_true",
                    help="train the pinned parity-protocol model first")
    ap.add_argument("--windows", type=int, default=64,
                    help="parity-check windows")
    ap.add_argument("--bits", type=int, default=15, choices=(15, 7),
                    help="weight format: 15 = Q15/int16 (paper), 7 = Q7/int8")
    args = ap.parse_args()

    # 1+2: model -> compression pipeline -> ONE artifact (the same
    # reference recipe the golden fixtures pin, so the Q15 default is
    # bit-identical to the checked-in golden image)
    if args.trained:
        params, calib = verify.protocol_model()
        art = build_reference_artifact(params=params, calib=calib,
                                       bits=args.bits)
    else:
        art = build_reference_artifact(seed=0, bits=args.bits)
    os.makedirs(args.outdir, exist_ok=True)
    blob = art.save(os.path.join(args.outdir, "model.fgar"))
    print(art.summary())
    print(f"artifact: {len(blob)} bytes -> {args.outdir}/model.fgar "
          f"(sha256 {art.sha256()[:16]}...)")
    srep = art.size_report()
    print(f"  weights {srep['weight_bytes_packed']} B packed "
          f"({srep['q_format']}; paper class: 566 B), "
          f"LUTs {srep['lut_bytes']} B, passes: "
          f"{' -> '.join(art.passes_applied())}")

    # 3: artifact -> wire image + budget audit (raises if unflashable)
    img = build_image(art)
    with open(os.path.join(args.outdir, "model.fgrn"), "wb") as f:
        f.write(img.to_bytes())
    rep = size_report(img)
    print(f"wire image: {rep['total_bytes']} bytes -> "
          f"{args.outdir}/model.fgrn (bits={rep['bits']})")
    for engine in ("float", "int"):
        audit = audit_platforms(img, ("avr", "msp430"), engine=engine)
        for key, a in audit.items():
            print(f"  [{engine:5s}] {key:6s}: flash {a['image_bytes']}/"
                  f"{a['flash_capacity'] - a['code_reserve']} B, "
                  f"sram {a['sram_needed']}/{a['sram_capacity']} B  OK")

    # 4: emit C for every target x engine
    for target in ("avr", "msp430", "host"):
        for engine in ("float", "int"):
            d = os.path.join(args.outdir, target, engine)
            paths = emit_c.write_sources(img, d, target=target, engine=engine)
            print(f"  emitted {target}/{engine}: "
                  f"{', '.join(os.path.basename(p) for p in paths)}")

    # 5: host parity (the artifact is the report's single source of truth)
    if emit_c.find_cc() is None:
        print("no C compiler on PATH — skipping the compile+parity check")
        return
    windows = hapt.load("test", n=args.windows).windows
    report = verify.run_parity(art, windows=windows, use_fp32=False)
    print("parity over", report["n_windows"], "windows:")
    for k, v in report["bitwise"].items():
        print(f"  bitwise {k}: {'OK' if v else 'MISMATCH'}")
    for k, v in report["pairwise"].items():
        print(f"  argmax {k}: {v['agree']:.4f}")
    with open(os.path.join(args.outdir, "parity.json"), "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.outdir}/parity.json")


if __name__ == "__main__":
    main()
