"""Streaming HAR demo: a fleet of live 50 Hz sensors served by one engine.

    PYTHONPATH=src python examples/streaming_har_demo.py [--streams 12]

Trains a small low-rank FastGRNN, deploys it (Q15 PTQ), then replays HAPT
test windows as *interleaved live streams*: sensors come online at
staggered times, push one tri-axial sample per tick, occasionally stall
(dropped radio packets — their hidden state is held bit-for-bit), finish
and detach, and new sensors are admitted from the pending queue into the
freed slots.  Every prediction is bit-identical to running the paper's
scalar C-equivalent runtime on the same samples.
"""
import argparse
import collections

import numpy as np

from repro.core import fastgrnn as fg, pipeline as pl
from repro.core.qruntime import QRuntime
from repro.data import hapt
from repro.serve.streaming import StreamingEngine, StreamingConfig

parser = argparse.ArgumentParser()
parser.add_argument("--streams", type=int, default=12)
parser.add_argument("--slots", type=int, default=4)
parser.add_argument("--epochs", type=int, default=30)
args = parser.parse_args()

# 1. train + deploy (paper config: H=16, r_w=2, r_u=8, Q15 PTQ)
train = hapt.load("train", n=1500)
test = hapt.load("test", n=args.streams)
cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
res = pl.train_fastgrnn(cfg, train.windows, train.labels,
                        epochs=args.epochs, seed=0)
rt = pl.deploy(res.params, train.windows[:5])

# 2. streaming engine: fewer slots than sensors -> continuous batching
eng = StreamingEngine(rt.qp, StreamingConfig(max_slots=args.slots))

# 3. replay test windows as staggered, stalling live streams
rng = np.random.default_rng(0)
cursors = {}                       # stream_id -> next sample index
for i in range(args.streams):
    cursors[f"sensor-{i:02d}"] = 0
start_tick = {f"sensor-{i:02d}": int(rng.integers(0, 40))
              for i in range(args.streams)}
windows = {f"sensor-{i:02d}": test.windows[i] for i in range(args.streams)}
labels = {f"sensor-{i:02d}": int(test.labels[i]) for i in range(args.streams)}

events, tick = [], 0
attached = set()
while len(events) < args.streams:
    for sid, t0 in start_tick.items():
        if tick == t0:
            eng.attach(sid, total_steps=128)
            attached.add(sid)
            print(f"[tick {tick:4d}] {sid} online "
                  f"({eng.n_active} active / {eng.n_pending} pending)")
    for sid in sorted(attached):
        c = cursors[sid]
        if c < 128 and rng.random() > 0.15:      # 15% chance of a stall
            eng.feed(sid, windows[sid][c])
            cursors[sid] = c + 1
    for ev in eng.step():
        events.append(ev)
        cls = hapt.CLASSES[ev.prediction]
        truth = hapt.CLASSES[labels[ev.stream_id]]
        flag = "warm" if ev.warm else "COLD"
        ok = "ok " if ev.prediction == labels[ev.stream_id] else "MISS"
        print(f"[tick {tick:4d}] {ev.stream_id} -> {cls:<10s} "
              f"({flag}, truth {truth:<10s} {ok}, "
              f"{eng.n_active} active / {eng.n_pending} pending)")
    tick += 1

# 4. verify the streaming fleet against the offline scalar runtime
by_id = {e.stream_id: e for e in events}
agree = offline_hits = 0
for sid, w in windows.items():
    offline = rt.predict(w)
    agree += int(by_id[sid].prediction == offline)
    offline_hits += int(offline == labels[sid])
counts = collections.Counter(e.kind for e in events)
print(f"\n{len(events)} predictions ({dict(counts)}), "
      f"{tick} ticks, stats: {eng.stats()}")
print(f"streaming-vs-offline scalar agreement: "
      f"{agree}/{args.streams} (bit-exact contract)")
print(f"accuracy: streaming {sum(int(by_id[s].prediction == labels[s]) for s in windows)}"
      f"/{args.streams}, offline {offline_hits}/{args.streams}")
