"""Quickstart: the paper's L-S-Q pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Trains a low-rank FastGRNN on (synthetic) HAPT for a few epochs, applies
IHT sparsity + calibrated Q15 quantization, and runs the deterministic
integer runtime — printing F1 and FP32-vs-Q15 agreement.
"""
import numpy as np

from repro.core import fastgrnn as fg, pipeline as pl, compression as comp
from repro.data import hapt

# 1. data (synthetic HAPT: 128-sample tri-axial windows @ 50 Hz, 6 classes)
train = hapt.load("train", n=2000)
test = hapt.load("test", n=600)

# 2. train the low-rank cell (paper config: H=16, r_w=2, r_u=8)
cfg = fg.FastGRNNConfig(rank_w=2, rank_u=8)
iht = comp.IHTConfig(target_sparsity=0.5, ramp_epochs=20)
result = pl.train_fastgrnn(cfg, train.windows, train.labels,
                           epochs=40, seed=0, iht=iht)

# 3. deploy: per-tensor Q15 + activation calibration -> integer runtime
runtime = pl.deploy(result.params, train.windows[:5])

# 4. evaluate both paths
fp32_pred = pl.predict_fp32(result.params, test.windows)
q15_pred = runtime.predict_batch(test.windows)
print(f"FP32  macro-F1: {pl.macro_f1(test.labels, fp32_pred):.3f}")
print(f"Q15   macro-F1: {pl.macro_f1(test.labels, q15_pred):.3f}")
print(f"FP32-vs-Q15 prediction agreement: "
      f"{pl.agreement(fp32_pred, q15_pred)*100:.2f}%")
print(f"deployed weights: "
      f"{comp.deployed_param_count(result.params, result.masks) * 2} bytes")
