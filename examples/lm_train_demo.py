"""LM-scale demo: train a reduced assigned architecture with the full
production trainer (checkpointing, straggler monitor, deterministic
seekable data, optional IHT sparsity) on CPU.

    PYTHONPATH=src python examples/lm_train_demo.py --arch qwen2-1.5b \
        --steps 200

Use --arch with any of the 10 assigned ids; the config is reduced to a
CPU-sized model of the same family (the full configs are exercised via
the 512-chip dry-run: python -m repro.launch.dryrun).
"""
import argparse

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.data import tokens
from repro.models import registry
from repro.train.optimizer import AdamConfig
from repro.train.trainer import Trainer, TrainerConfig

parser = argparse.ArgumentParser()
parser.add_argument("--arch", default="qwen2-1.5b", choices=list(C.ARCHS))
parser.add_argument("--steps", type=int, default=200)
parser.add_argument("--batch", type=int, default=8)
parser.add_argument("--seq", type=int, default=64)
parser.add_argument("--ckpt-dir", default="/tmp/repro_lm_demo")
args = parser.parse_args()

cfg = C.reduced(C.get(args.arch), d_model=128, num_layers=4,
                num_heads=4 if C.get(args.arch).num_heads else 0)
print(f"arch={cfg.name} family={cfg.family} reduced to "
      f"{cfg.num_layers}L x d{cfg.d_model}")

tcfg = tokens.TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                global_batch=args.batch)
acfg = AdamConfig(lr=1e-3, warmup_steps=20)
step = jax.jit(registry.make_train_step(cfg, acfg), donate_argnums=(0, 1))


def batch_fn(s):
    b = tokens.lm_batch(tcfg, s)
    out = {k: jnp.asarray(v) for k, v in b.items()}
    if cfg.family == "vlm":
        out["patch_embeds"] = jnp.zeros((args.batch, cfg.num_patches,
                                         cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            jax.random.normal(jax.random.PRNGKey(s),
                              (args.batch, args.seq, cfg.d_model)))
    return out


trainer = Trainer(
    TrainerConfig(total_steps=args.steps, checkpoint_every=50,
                  checkpoint_dir=args.ckpt_dir, log_every=20, adam=acfg),
    init_params_fn=lambda: registry.init(cfg, jax.random.PRNGKey(0)),
    step_fn=step, batch_fn=batch_fn,
    on_straggler=lambda s, dt, v: print(f"[straggler] step {s}: {dt:.2f}s"))

hist = trainer.run()
losses = [h["loss"] for h in hist if "loss" in h]
print(f"step 0 loss {losses[0]:.3f} -> step {len(losses)-1} "
      f"loss {losses[-1]:.3f}")
print(f"checkpoints in {args.ckpt_dir} (restart this script to resume)")
